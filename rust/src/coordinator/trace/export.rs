//! Trace export: OTLP-shaped JSON and a delta+RLE-compressed binary
//! form, plus the per-lane step-time breakdown behind `toma-serve trace`.
//!
//! Same zero-dependency serialization discipline as `runtime/artifact.rs`:
//! hand-rolled writers, `util::json` for parsing, descriptive errors, and
//! round-trip tests pinning both formats. 64-bit fields are emitted as
//! JSON *strings* (OTLP convention — JSON numbers are lossy past 2^53);
//! lane hashes render as fixed-width hex.
//!
//! The binary layout is columnar: per-field columns over the span list,
//! run-length encoded where values repeat (site/kind/lane — traces are
//! dominated by long same-lane runs) and zigzag-delta varint encoded
//! where values are near-monotonic (id/step/start offsets). A typical
//! serving trace compresses ~10x against its OTLP JSON rendering.

use std::collections::BTreeMap;

use super::span::{Site, Span, SpanKind};
use crate::report::{fmt_secs, Table};
use crate::util::error::Result;
use crate::util::json::Json;

/// Binary trace magic: format version bumps the trailing digits.
pub const MAGIC: &[u8; 8] = b"TOMATR01";

// ---------------------------------------------------------------------
// varint / zigzag primitives
// ---------------------------------------------------------------------

fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        out.push((v as u8) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

fn get_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *buf
            .get(*pos)
            .ok_or_else(|| crate::anyhow!("trace binary truncated in varint at byte {}", *pos))?;
        *pos += 1;
        crate::ensure!(shift < 64, "trace binary varint overflows u64 at byte {}", *pos);
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

// ---------------------------------------------------------------------
// columns
// ---------------------------------------------------------------------

/// RLE column: (run length, value) pairs until `n` values are covered.
fn put_rle(out: &mut Vec<u8>, values: impl Iterator<Item = u64>) {
    let mut run: Option<(u64, u64)> = None;
    for v in values {
        match run {
            Some((rv, n)) if rv == v => run = Some((rv, n + 1)),
            Some((rv, n)) => {
                put_varint(out, n);
                put_varint(out, rv);
                run = Some((v, 1));
            }
            None => run = Some((v, 1)),
        }
    }
    if let Some((rv, n)) = run {
        put_varint(out, n);
        put_varint(out, rv);
    }
}

fn get_rle(buf: &[u8], pos: &mut usize, n: usize) -> Result<Vec<u64>> {
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let run = get_varint(buf, pos)?;
        let v = get_varint(buf, pos)?;
        crate::ensure!(
            run >= 1 && out.len() + run as usize <= n,
            "trace binary RLE run of {run} overflows column of {n}"
        );
        out.extend(std::iter::repeat(v).take(run as usize));
    }
    Ok(out)
}

/// Delta column: zigzag varint of successive differences.
fn put_delta(out: &mut Vec<u8>, values: impl Iterator<Item = u64>) {
    let mut prev = 0i64;
    for v in values {
        let v = v as i64;
        put_varint(out, zigzag(v.wrapping_sub(prev)));
        prev = v;
    }
}

fn get_delta(buf: &[u8], pos: &mut usize, n: usize) -> Result<Vec<u64>> {
    let mut out = Vec::with_capacity(n);
    let mut prev = 0i64;
    for _ in 0..n {
        prev = prev.wrapping_add(unzigzag(get_varint(buf, pos)?));
        out.push(prev as u64);
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// binary format
// ---------------------------------------------------------------------

/// Serialize spans (+ the dropped-span count) into the compressed
/// columnar binary form.
pub fn encode_binary(spans: &[Span], dropped: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + spans.len() * 4);
    out.extend_from_slice(MAGIC);
    put_varint(&mut out, spans.len() as u64);
    put_varint(&mut out, dropped);
    put_rle(&mut out, spans.iter().map(|s| s.site as u64));
    put_rle(&mut out, spans.iter().map(|s| s.kind as u64));
    put_rle(&mut out, spans.iter().map(|s| s.lane));
    put_delta(&mut out, spans.iter().map(|s| s.id));
    put_delta(&mut out, spans.iter().map(|s| s.step as u64));
    put_delta(&mut out, spans.iter().map(|s| s.start_us));
    put_delta(&mut out, spans.iter().map(|s| s.dur_us));
    out
}

/// Inverse of [`encode_binary`]. Returns `(spans, dropped)`.
pub fn decode_binary(buf: &[u8]) -> Result<(Vec<Span>, u64)> {
    crate::ensure!(
        buf.len() >= MAGIC.len() && &buf[..MAGIC.len()] == MAGIC,
        "not a ToMA binary trace: expected magic {:?}",
        std::str::from_utf8(MAGIC).unwrap()
    );
    let mut pos = MAGIC.len();
    let n = get_varint(buf, &mut pos)? as usize;
    let dropped = get_varint(buf, &mut pos)?;
    let sites = get_rle(buf, &mut pos, n)?;
    let kinds = get_rle(buf, &mut pos, n)?;
    let lanes = get_rle(buf, &mut pos, n)?;
    let ids = get_delta(buf, &mut pos, n)?;
    let steps = get_delta(buf, &mut pos, n)?;
    let starts = get_delta(buf, &mut pos, n)?;
    let durs = get_delta(buf, &mut pos, n)?;
    let mut spans = Vec::with_capacity(n);
    for i in 0..n {
        let site = Site::from_u8(sites[i] as u8)
            .ok_or_else(|| crate::anyhow!("trace binary: invalid site byte {}", sites[i]))?;
        let kind = SpanKind::from_u8(kinds[i] as u8)
            .ok_or_else(|| crate::anyhow!("trace binary: invalid kind byte {}", kinds[i]))?;
        spans.push(Span {
            site,
            kind,
            lane: lanes[i],
            id: ids[i],
            step: steps[i] as u32,
            start_us: starts[i],
            dur_us: durs[i],
        });
    }
    Ok((spans, dropped))
}

// ---------------------------------------------------------------------
// OTLP-shaped JSON
// ---------------------------------------------------------------------

fn push_attr_str(out: &mut String, key: &str, value: &str, last: bool) {
    out.push_str(&format!(
        "{{\"key\": \"{key}\", \"value\": {{\"stringValue\": \"{value}\"}}}}{}",
        if last { "" } else { ", " }
    ));
}

fn push_attr_int(out: &mut String, key: &str, value: u64, last: bool) {
    // OTLP JSON renders 64-bit ints as strings.
    out.push_str(&format!(
        "{{\"key\": \"{key}\", \"value\": {{\"intValue\": \"{value}\"}}}}{}",
        if last { "" } else { ", " }
    ));
}

/// Serialize spans into an OTLP-shaped JSON document (one resource, one
/// scope, one span entry per record; ToMA fields ride as attributes).
pub fn encode_json(spans: &[Span], dropped: u64) -> String {
    let mut rows = Vec::with_capacity(spans.len());
    for (i, s) in spans.iter().enumerate() {
        let mut attrs = String::new();
        push_attr_str(&mut attrs, "toma.site", s.site.as_str(), false);
        push_attr_str(&mut attrs, "toma.lane", &format!("{:016x}", s.lane), false);
        push_attr_int(&mut attrs, "toma.id", s.id, false);
        push_attr_int(&mut attrs, "toma.step", s.step as u64, true);
        rows.push(format!(
            "        {{\"name\": \"{name}\", \"traceId\": \"{lane:016x}{lane:016x}\", \
             \"spanId\": \"{sid:016x}\", \"startTimeUnixNano\": \"{start}\", \
             \"endTimeUnixNano\": \"{end}\", \"attributes\": [{attrs}]}}",
            name = s.kind.as_str(),
            lane = s.lane,
            sid = i as u64 + 1,
            start = s.start_us.saturating_mul(1000),
            end = s.end_us().saturating_mul(1000),
        ));
    }
    format!(
        "{{\"resourceSpans\": [{{\
         \"resource\": {{\"attributes\": [{{\"key\": \"service.name\", \
         \"value\": {{\"stringValue\": \"toma-serve\"}}}}]}}, \
         \"scopeSpans\": [{{\"scope\": {{\"name\": \"toma.coordinator\"}}, \"spans\": [\n{}\n\
         ]}}]}}], \"droppedSpans\": \"{}\"}}\n",
        rows.join(",\n"),
        dropped
    )
}

fn attr_map(span: &Json) -> BTreeMap<String, String> {
    let mut out = BTreeMap::new();
    let Some(attrs) = span.get("attributes").and_then(|a| a.as_arr()) else {
        return out;
    };
    for a in attrs {
        let Some(key) = a.get("key").and_then(|k| k.as_str()) else {
            continue;
        };
        let Some(value) = a.get("value") else { continue };
        let v = value
            .get("stringValue")
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .or_else(|| value.get("intValue").and_then(|v| v.as_str()).map(str::to_string));
        if let Some(v) = v {
            out.insert(key.to_string(), v);
        }
    }
    out
}

fn parse_u64(field: &str, v: &str) -> Result<u64> {
    v.parse::<u64>().map_err(|e| crate::anyhow!("trace JSON: bad {field} {v:?}: {e}"))
}

/// Inverse of [`encode_json`]. Returns `(spans, dropped)`.
pub fn decode_json(text: &str) -> Result<(Vec<Span>, u64)> {
    let doc = Json::parse(text)?;
    let dropped = match doc.get("droppedSpans") {
        Some(d) => match d.as_str() {
            Some(s) => parse_u64("droppedSpans", s)?,
            None => d.as_f64().unwrap_or(0.0) as u64,
        },
        None => 0,
    };
    let mut spans = Vec::new();
    let resources = doc
        .get("resourceSpans")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| crate::anyhow!("trace JSON: missing resourceSpans array"))?;
    for res in resources {
        let scopes = res
            .get("scopeSpans")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| crate::anyhow!("trace JSON: missing scopeSpans array"))?;
        for scope in scopes {
            let rows = scope
                .get("spans")
                .and_then(|s| s.as_arr())
                .ok_or_else(|| crate::anyhow!("trace JSON: missing spans array"))?;
            for row in rows {
                let name = row
                    .get("name")
                    .and_then(|n| n.as_str())
                    .ok_or_else(|| crate::anyhow!("trace JSON: span without name"))?;
                let kind = SpanKind::parse(name)
                    .ok_or_else(|| crate::anyhow!("trace JSON: unknown span kind {name:?}"))?;
                let attrs = attr_map(row);
                let site_s = attrs
                    .get("toma.site")
                    .ok_or_else(|| crate::anyhow!("trace JSON: span missing toma.site"))?;
                let site = Site::parse(site_s)
                    .ok_or_else(|| crate::anyhow!("trace JSON: unknown site {site_s:?}"))?;
                let lane_s = attrs
                    .get("toma.lane")
                    .ok_or_else(|| crate::anyhow!("trace JSON: span missing toma.lane"))?;
                let lane = u64::from_str_radix(lane_s, 16)
                    .map_err(|e| crate::anyhow!("trace JSON: bad toma.lane {lane_s:?}: {e}"))?;
                let id = parse_u64("toma.id", attrs.get("toma.id").map_or("0", String::as_str))?;
                let step =
                    parse_u64("toma.step", attrs.get("toma.step").map_or("0", String::as_str))?;
                let start_ns = row
                    .get("startTimeUnixNano")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| crate::anyhow!("trace JSON: span missing startTimeUnixNano"))?;
                let end_ns = row
                    .get("endTimeUnixNano")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| crate::anyhow!("trace JSON: span missing endTimeUnixNano"))?;
                let start_us = parse_u64("startTimeUnixNano", start_ns)? / 1000;
                let end_us = parse_u64("endTimeUnixNano", end_ns)? / 1000;
                spans.push(Span {
                    site,
                    kind,
                    lane,
                    id,
                    step: step as u32,
                    start_us,
                    dur_us: end_us.saturating_sub(start_us),
                });
            }
        }
    }
    Ok((spans, dropped))
}

/// Load a trace from raw file bytes, sniffing binary (magic) vs JSON.
pub fn decode_auto(bytes: &[u8]) -> Result<(Vec<Span>, u64)> {
    if bytes.len() >= MAGIC.len() && &bytes[..MAGIC.len()] == MAGIC {
        return decode_binary(bytes);
    }
    let text = std::str::from_utf8(bytes)
        .map_err(|e| crate::anyhow!("trace file is neither binary (bad magic) nor UTF-8: {e}"))?;
    decode_json(text)
}

// ---------------------------------------------------------------------
// per-lane critical-path / self-time breakdown
// ---------------------------------------------------------------------

const KIND_COUNT: usize = 10;

/// Aggregate self-time per lane and per kind, plus the slowest cohort
/// step's critical path — the `toma-serve trace` inspector body.
pub fn breakdown(spans: &[Span], dropped: u64) -> String {
    let mut lanes: BTreeMap<u64, ([u64; KIND_COUNT], [u64; KIND_COUNT])> = BTreeMap::new();
    for s in spans {
        let (dur, count) = lanes.entry(s.lane).or_insert(([0; KIND_COUNT], [0; KIND_COUNT]));
        dur[s.kind as usize] += s.dur_us;
        count[s.kind as usize] += 1;
    }
    let mut t = Table::new("per-lane self-time (where each lane's budget went)").headers(&[
        "lane",
        "spans",
        "queue-wait",
        "formation",
        "select",
        "refresh",
        "step(gemm)",
        "retry",
        "fault",
        "cache-hit",
        "cache-miss(n)",
    ]);
    for (lane, (dur, count)) in &lanes {
        let spans_n: u64 = count.iter().sum();
        t.row(vec![
            format!("{lane:016x}"),
            spans_n.to_string(),
            fmt_secs(dur[SpanKind::QueueWait as usize] as f64 * 1e-6),
            fmt_secs(dur[SpanKind::Formation as usize] as f64 * 1e-6),
            fmt_secs(dur[SpanKind::Select as usize] as f64 * 1e-6),
            fmt_secs(dur[SpanKind::Refresh as usize] as f64 * 1e-6),
            fmt_secs(dur[SpanKind::Step as usize] as f64 * 1e-6),
            fmt_secs(dur[SpanKind::Retry as usize] as f64 * 1e-6),
            fmt_secs(dur[SpanKind::Fault as usize] as f64 * 1e-6),
            // Hit time is the probe+install cost that replaced a Select;
            // misses are zero-duration markers, so a count is the signal.
            fmt_secs(dur[SpanKind::CacheHit as usize] as f64 * 1e-6),
            count[SpanKind::CacheMiss as usize].to_string(),
        ]);
    }
    let mut out = String::new();
    out.push_str(&format!(
        "{} spans across {} lane(s), {} dropped\n\n",
        spans.len(),
        lanes.len(),
        dropped
    ));
    out.push_str(&t.render());
    if let Some(line) = slowest_step(spans) {
        out.push('\n');
        out.push_str(&line);
        out.push('\n');
    }
    out
}

/// Critical path of the slowest cohort step: its GEMM (`Step`) span plus
/// the same-(lane, step) plan spans and the queue waits that preceded it.
fn slowest_step(spans: &[Span]) -> Option<String> {
    let gemm = spans
        .iter()
        .filter(|s| s.kind == SpanKind::Step && s.site != Site::Server)
        .max_by_key(|s| s.dur_us)?;
    let mut select_us = 0u64;
    let mut refresh_us = 0u64;
    let mut queue_us = 0u64;
    for s in spans {
        if s.lane != gemm.lane {
            continue;
        }
        match s.kind {
            SpanKind::Select if s.step == gemm.step => select_us += s.dur_us,
            SpanKind::Refresh if s.step == gemm.step => refresh_us += s.dur_us,
            SpanKind::QueueWait if s.end_us() <= gemm.start_us => queue_us += s.dur_us,
            _ => {}
        }
    }
    let total = (gemm.dur_us + select_us + refresh_us).max(1);
    let share = |v: u64| format!("{:.0}%", v as f64 * 100.0 / total as f64);
    Some(format!(
        "slowest cohort step: lane {:016x} step {} — critical path {} = select {} ({}) + \
         refresh {} ({}) + gemm {} ({}); members waited {} in queue beforehand",
        gemm.lane,
        gemm.step,
        fmt_secs(total as f64 * 1e-6),
        fmt_secs(select_us as f64 * 1e-6),
        share(select_us),
        fmt_secs(refresh_us as f64 * 1e-6),
        share(refresh_us),
        fmt_secs(gemm.dur_us as f64 * 1e-6),
        share(gemm.dur_us),
        fmt_secs(queue_us as f64 * 1e-6),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trace::span::lane_hash;

    fn sample_spans() -> Vec<Span> {
        let lane_a = lane_hash("lane-a");
        let lane_b = lane_hash("lane-b");
        let mut spans = vec![];
        for step in 0..4u32 {
            let base = 1000 * step as u64;
            spans.push(Span {
                site: Site::Frontend,
                kind: SpanKind::Submit,
                lane: lane_a,
                id: 100 + step as u64,
                step: 0,
                start_us: base,
                dur_us: 0,
            });
            spans.push(Span {
                site: Site::Scheduler,
                kind: SpanKind::QueueWait,
                lane: lane_a,
                id: 100 + step as u64,
                step: 0,
                start_us: base,
                dur_us: 40,
            });
            spans.push(Span {
                site: Site::Scheduler,
                kind: SpanKind::Select,
                lane: lane_a,
                id: 7,
                step,
                start_us: base + 50,
                dur_us: 300,
            });
            spans.push(Span {
                site: Site::Scheduler,
                kind: SpanKind::Step,
                lane: lane_a,
                id: 7,
                step,
                start_us: base + 350,
                dur_us: 200 + step as u64,
            });
        }
        // PR 8 cache spans: a hit (probe+install time) and a zero-duration
        // miss marker — the breakdown must index both without panicking.
        spans.push(Span {
            site: Site::Scheduler,
            kind: SpanKind::CacheHit,
            lane: lane_a,
            id: 7,
            step: 2,
            start_us: 2040,
            dur_us: 12,
        });
        spans.push(Span {
            site: Site::Scheduler,
            kind: SpanKind::CacheMiss,
            lane: lane_a,
            id: 7,
            step: 1,
            start_us: 1050,
            dur_us: 0,
        });
        spans.push(Span {
            site: Site::Server,
            kind: SpanKind::Step,
            lane: lane_b,
            id: 9,
            step: 0,
            start_us: 5000,
            dur_us: 2500,
        });
        spans.push(Span {
            site: Site::Fault,
            kind: SpanKind::Fault,
            lane: lane_b,
            id: 9,
            step: 0,
            start_us: 5100,
            dur_us: 2,
        });
        spans
    }

    #[test]
    fn binary_roundtrip_identical() {
        let spans = sample_spans();
        let buf = encode_binary(&spans, 3);
        let (back, dropped) = decode_binary(&buf).expect("decode");
        assert_eq!(back, spans);
        assert_eq!(dropped, 3);
    }

    #[test]
    fn json_roundtrip_identical() {
        let spans = sample_spans();
        let text = encode_json(&spans, 5);
        let (back, dropped) = decode_json(&text).expect("decode");
        assert_eq!(back, spans);
        assert_eq!(dropped, 5);
    }

    #[test]
    fn auto_detects_both_formats() {
        let spans = sample_spans();
        let (b, _) = decode_auto(&encode_binary(&spans, 0)).expect("binary");
        let (j, _) = decode_auto(encode_json(&spans, 0).as_bytes()).expect("json");
        assert_eq!(b, spans);
        assert_eq!(j, spans);
    }

    #[test]
    fn empty_trace_roundtrips() {
        let (b, d) = decode_binary(&encode_binary(&[], 9)).expect("binary");
        assert!(b.is_empty());
        assert_eq!(d, 9);
        let (j, d) = decode_json(&encode_json(&[], 9)).expect("json");
        assert!(j.is_empty());
        assert_eq!(d, 9);
    }

    #[test]
    fn binary_smaller_than_json() {
        let spans = sample_spans();
        let bin = encode_binary(&spans, 0);
        let json = encode_json(&spans, 0);
        assert!(
            bin.len() * 4 < json.len(),
            "delta+RLE should compress well: {} vs {}",
            bin.len(),
            json.len()
        );
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode_binary(b"NOTATRACE").is_err());
        assert!(decode_json("{\"resourceSpans\": 3}").is_err());
        let mut buf = encode_binary(&sample_spans(), 0);
        buf.truncate(buf.len() - 2);
        assert!(decode_binary(&buf).is_err(), "truncated binary must not decode");
    }

    #[test]
    fn varint_extremes() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            let mut buf = vec![];
            put_varint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(get_varint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
        for v in [0i64, 1, -1, 63, -64, i64::MAX, i64::MIN] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
    }

    #[test]
    fn breakdown_names_slowest_scheduler_step() {
        let spans = sample_spans();
        let report = breakdown(&spans, 1);
        // Slowest *scheduler* step is step 3 (dur 203); the 2.5 ms server
        // span must not win — it is a per-request step, not a cohort step.
        assert!(report.contains("step 3"), "report:\n{report}");
        assert!(report.contains("slowest cohort step"), "report:\n{report}");
        assert!(report.contains("1 dropped"), "report:\n{report}");
    }

    #[test]
    fn breakdown_empty_is_calm() {
        let report = breakdown(&[], 0);
        assert!(report.contains("0 spans"));
    }
}
