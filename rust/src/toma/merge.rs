//! Attention-based merge (Sec. 4.2.1): the dense-GEMM formulation that is
//! the paper's core systems contribution.
//!
//! ```text
//! A  = softmax_col(D_n X_n^T / tau)     (D x N)
//! A~ = row_normalize(A)
//! X_merged = A~ X                        (D x d) — one GEMM
//! ```
//!
//! Contrast with `baselines::tome`, which needs argsort + gather +
//! scatter-add for the same effect (Table 6).

use crate::tensor::ops::{
    gather_rows, l2_normalize_rows, matmul, normalize_rows, softmax_cols,
};

/// The merge operator for one region: both the column-softmax attention `a`
/// and the row-normalized merge weights `a_tilde`, each (k x n) row-major.
#[derive(Clone, Debug)]
pub struct MergeWeights {
    pub a: Vec<f32>,
    pub a_tilde: Vec<f32>,
    pub k: usize,
    pub n: usize,
}

/// Build merge weights from features x (n x d) and destination indices.
pub fn build_merge_weights(x: &[f32], n: usize, d: usize, idx: &[usize], tau: f32) -> MergeWeights {
    assert_eq!(x.len(), n * d);
    let k = idx.len();
    let mut xn = x.to_vec();
    l2_normalize_rows(&mut xn, n, d);
    // Fold the 1/tau temperature into the k x d destination rows before
    // the GEMM: O(k*d) scales instead of an O(k*n) pass over the logits.
    let mut dn = gather_rows(&xn, d, idx);
    let inv_tau = 1.0 / tau;
    for v in &mut dn {
        *v *= inv_tau;
    }
    // logits = (D_n / tau) X_n^T  (k x n)
    let mut a = crate::tensor::ops::matmul_bt(&dn, &xn, k, d, n);
    softmax_cols(&mut a, k, n);
    let mut a_tilde = a.clone();
    normalize_rows(&mut a_tilde, k, n);
    MergeWeights { a, a_tilde, k, n }
}

/// X_merged = A~ X: (k x n) @ (n x d) — the single-GEMM merge.
pub fn merge(w: &MergeWeights, x: &[f32], d: usize) -> Vec<f32> {
    assert_eq!(x.len(), w.n * d);
    matmul(&w.a_tilde, x, w.k, w.n, d)
}

/// Merge into a caller-provided buffer (allocation-free hot path).
pub fn merge_into(w: &MergeWeights, x: &[f32], d: usize, out: &mut [f32]) {
    crate::tensor::ops::matmul_into(&w.a_tilde, x, out, w.k, w.n, d);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::toma::facility::{fl_select, similarity_matrix};
    use crate::util::{prop, Pcg64};

    fn setup(n: usize, d: usize, k: usize, tau: f32, seed: u64) -> (Vec<f32>, MergeWeights) {
        let x = Pcg64::new(seed).normal_vec(n * d);
        let sim = similarity_matrix(&x, n, d);
        let idx = fl_select(&sim, n, k);
        let w = build_merge_weights(&x, n, d, &idx, tau);
        (x, w)
    }

    #[test]
    fn columns_sum_to_one() {
        let (_, w) = setup(20, 8, 5, 0.1, 0);
        for j in 0..w.n {
            let s: f32 = (0..w.k).map(|i| w.a[i * w.n + j]).sum();
            assert!((s - 1.0).abs() < 1e-4, "col {j}: {s}");
        }
    }

    #[test]
    fn rows_sum_to_one() {
        let (_, w) = setup(20, 8, 5, 0.1, 1);
        for i in 0..w.k {
            let s: f32 = w.a_tilde[i * w.n..(i + 1) * w.n].iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row {i}: {s}");
        }
    }

    #[test]
    fn weights_nonnegative() {
        let (_, w) = setup(16, 4, 4, 0.1, 2);
        assert!(w.a.iter().all(|v| *v >= 0.0));
        assert!(w.a_tilde.iter().all(|v| *v >= 0.0));
    }

    #[test]
    fn merged_tokens_are_convex_combinations() {
        let (x, w) = setup(16, 4, 4, 0.1, 3);
        let xm = merge(&w, &x, 4);
        for c in 0..4 {
            let lo = (0..16).map(|i| x[i * 4 + c]).fold(f32::INFINITY, f32::min);
            let hi = (0..16)
                .map(|i| x[i * 4 + c])
                .fold(f32::NEG_INFINITY, f32::max);
            for r in 0..4 {
                let v = xm[r * 4 + c];
                assert!(v >= lo - 1e-4 && v <= hi + 1e-4);
            }
        }
    }

    #[test]
    fn sharp_tau_recovers_destinations() {
        // tau -> 0 with k == n: A~ ~ I, so merged ~ original tokens.
        let x = Pcg64::new(4).normal_vec(10 * 6);
        let idx: Vec<usize> = (0..10).collect();
        let w = build_merge_weights(&x, 10, 6, &idx, 0.005);
        let xm = merge(&w, &x, 6);
        for (a, b) in xm.iter().zip(&x) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn merge_into_matches_merge() {
        let (x, w) = setup(20, 8, 5, 0.1, 5);
        let out1 = merge(&w, &x, 8);
        let mut out2 = vec![0.0; 5 * 8];
        merge_into(&w, &x, 8, &mut out2);
        assert_eq!(out1, out2);
    }

    #[test]
    fn prop_merge_invariants() {
        prop::check("merge weights", 20, |g| {
            let n = g.usize_in(4, 24);
            let d = g.usize_in(2, 10);
            let k = g.usize_in(1, n);
            let tau = *g.pick(&[0.05f32, 0.1, 0.5, 1.0]);
            let x = g.normal_vec(n * d);
            let sim = similarity_matrix(&x, n, d);
            let idx = fl_select(&sim, n, k);
            let w = build_merge_weights(&x, n, d, &idx, tau);
            for j in 0..n {
                let s: f32 = (0..k).map(|i| w.a[i * n + j]).sum();
                prop::assert_prop((s - 1.0).abs() < 1e-3, "col softmax");
            }
            for i in 0..k {
                let s: f32 = w.a_tilde[i * n..(i + 1) * n].iter().sum();
                prop::assert_prop((s - 1.0).abs() < 1e-3, "row norm");
            }
            let xm = merge(&w, &x, d);
            prop::assert_prop(xm.iter().all(|v| v.is_finite()), "finite");
        });
    }
}
