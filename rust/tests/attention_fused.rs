//! Acceptance tests for the PR 9 fused streaming-tile attention path
//! (`tensor::attention`): fused-vs-materialized agreement inside the
//! pinned ≤1e-5 relative envelope across remainder shapes, bitwise
//! dispatch invariance and fold invariance of the fused path itself,
//! with-merge-plan composition through the host engine, `TOMA_ATTN`
//! override coherence, and the O(Bq·Bk + Bq·dh) scratch bound. Runs
//! artifact-free (tier 1).

use std::sync::Arc;

use toma::coordinator::scheduler::{HostEngine, DEFAULT_TAU};
use toma::coordinator::{EngineConfig, GenRequest};
use toma::model::HostUVit;
use toma::runtime::ModelInfo;
use toma::tensor::attention::{
    self, sdpa_into, sdpa_into_as, task_scratch_elems, thread_scratch_len, AttnMode, BK, BQ,
};
use toma::tensor::kernel::Dispatch;
use toma::util::Pcg64;

/// The pinned SDPA-level envelope: max_i |fused - mat| / (1 + |mat|).
const ENVELOPE: f32 = 1e-5;

type Qkv = (Vec<f32>, Vec<f32>, Vec<f32>);

fn qkv(seed: u64, samples: usize, nq: usize, nk: usize, d: usize) -> Qkv {
    let mut rng = Pcg64::new(seed);
    (
        rng.normal_vec(samples * nq * d),
        rng.normal_vec(samples * nk * d),
        rng.normal_vec(samples * nk * d),
    )
}

#[allow(clippy::too_many_arguments)]
fn run(
    mode: AttnMode,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    s: usize,
    nq: usize,
    nk: usize,
    d: usize,
    h: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; s * nq * d];
    sdpa_into(mode, q, k, v, s, nq, nk, d, h, &mut out);
    out
}

fn max_rel_err(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs() / (1.0 + y.abs()))
        .fold(0.0f32, f32::max)
}

/// Fused == materialized within the envelope across remainder shapes:
/// nq/nk/dh off every tile multiple, nk smaller than one key block and
/// smaller than the dot4 group, single-row q, multi-block k.
#[test]
fn fused_matches_materialized_within_envelope() {
    // (samples, h, nq, nk, d)
    let shapes: [(usize, usize, usize, usize, usize); 8] = [
        (1, 1, 1, 1, 3),       // single row, tiny head
        (1, 1, 5, 7, 8),       // everything below one tile
        (2, 2, 33, 64, 32),    // nq = BQ + 1 remainder row
        (1, 1, 32, 128, 16),   // exact BQ x BK tile
        (3, 2, 70, 200, 72),   // nk > BK, nothing a multiple
        (4, 1, 40, 100, 5),    // dh = 5: dot/axpy tails exercise
        (2, 1, 16, 9, 8),      // nk < BK and < dot4 group width
        (1, 2, 1, 300, 64),    // one q row streaming 3 key blocks
    ];
    for (i, &(s, h, nq, nk, d)) in shapes.iter().enumerate() {
        let (q, k, v) = qkv(0x9A + i as u64, s, nq, nk, d);
        let mat = run(AttnMode::Materialized, &q, &k, &v, s, nq, nk, d, h);
        let fus = run(AttnMode::Fused, &q, &k, &v, s, nq, nk, d, h);
        assert!(fus.iter().all(|x| x.is_finite()), "shape {i}: fused not finite");
        let err = max_rel_err(&fus, &mat);
        assert!(
            err <= ENVELOPE,
            "shape {i} ({s}x{h}x{nq}x{nk}x{d}): max rel err {err:e} > {ENVELOPE:e}"
        );
        // Sanity that the envelope is not vacuous: softmax outputs are
        // convex combinations of V rows, so magnitudes are O(1).
        assert!(mat.iter().any(|x| x.abs() > 1e-3), "shape {i}: degenerate reference");
    }
}

/// The fused path is **bitwise** dispatch-invariant: every fused
/// primitive (dot/dot4/row_max/scale/axpy) is pinned bit-identical
/// between the scalar reference and the AVX2 arm, and exp stays shared
/// scalar code — so TOMA_KERNEL never changes fused results.
#[test]
fn fused_is_bitwise_dispatch_invariant() {
    if !Dispatch::Avx2Fma.supported() {
        return; // one-armed host: nothing to compare
    }
    let shapes = [(2usize, 2usize, 33usize, 64usize, 32usize), (1, 1, 40, 200, 24)];
    for &(s, h, nq, nk, d) in &shapes {
        let (q, k, v) = qkv(0xD15, s, nq, nk, d);
        let mut a = vec![0.0f32; s * nq * d];
        let mut b = vec![0.0f32; s * nq * d];
        sdpa_into_as(AttnMode::Fused, Dispatch::Scalar, &q, &k, &v, s, nq, nk, d, h, &mut a);
        sdpa_into_as(AttnMode::Fused, Dispatch::Avx2Fma, &q, &k, &v, s, nq, nk, d, h, &mut b);
        assert_eq!(a, b, "fused results must be bit-identical across kernel dispatches");
    }
}

/// The fused path is **bitwise** fold-invariant: per-task arithmetic
/// never depends on how many samples share the call, so batched ==
/// per-sample — including across the serial/parallel fan-out threshold
/// (the batched shape crosses PAR_MIN_MACS, the per-sample ones may
/// not).
#[test]
fn fused_is_bitwise_fold_invariant() {
    let (s, h, nq, nk, d) = (2usize, 2usize, 48usize, 96usize, 32usize);
    let (q, k, v) = qkv(0xF01D, s, nq, nk, d);
    let batched = run(AttnMode::Fused, &q, &k, &v, s, nq, nk, d, h);
    for sample in 0..s {
        let solo = run(
            AttnMode::Fused,
            &q[sample * nq * d..(sample + 1) * nq * d],
            &k[sample * nk * d..(sample + 1) * nk * d],
            &v[sample * nk * d..(sample + 1) * nk * d],
            1,
            nq,
            nk,
            d,
            h,
        );
        assert_eq!(
            solo,
            batched[sample * nq * d..(sample + 1) * nq * d].to_vec(),
            "sample {sample}: fused fold-invariance broken"
        );
    }
}

/// Merge composition: fused attention on post-merge token counts through
/// the full host engine (ToMA plans installed), vs the same engine
/// materialized. One step bounds the compounding tightly; a full
/// 12-step generation must stay finite and close in relative L2.
#[test]
fn fused_composes_with_merge_plans() {
    let info = ModelInfo::synthetic("uvit_af", 4, 2, 16, 2, 3, 5);
    let model = Arc::new(HostUVit::synthetic(&info, 2, 4242));
    let mut base = EngineConfig::new("uvit_af", "toma", Some(0.5));
    base.steps = 1;
    let req = GenRequest::new("fused merge probe", 77);

    let gen = |cfg: &EngineConfig| {
        HostEngine::new(model.clone(), cfg.clone(), 4, DEFAULT_TAU)
            .expect("engine")
            .generate(&req)
            .expect("generate")
            .latent
    };
    // Single step: per-call SDPA error barely amplified by two blocks.
    let mat = gen(&base);
    let fus = gen(&base.clone().with_attn(AttnMode::Fused));
    assert!(fus.iter().all(|v| v.is_finite()));
    let err = max_rel_err(&fus, &mat);
    assert!(err <= 1e-4, "single-step merge+fused diverged: max rel err {err:e}");

    // Full generation: the envelope compounds across steps — assert a
    // loose relative-L2 bound and finiteness, not bit-anything.
    base.steps = 12;
    let mat = gen(&base);
    let fus = gen(&base.clone().with_attn(AttnMode::Fused));
    assert!(fus.iter().all(|v| v.is_finite()), "12-step fused trajectory must stay finite");
    let num: f32 = mat.iter().zip(&fus).map(|(a, b)| (a - b) * (a - b)).sum();
    let den: f32 = mat.iter().map(|a| a * a).sum::<f32>().max(1e-12);
    let rel_l2 = (num / den).sqrt();
    assert!(rel_l2 <= 5e-2, "12-step merge+fused drifted: rel L2 {rel_l2:e}");
}

/// `TOMA_ATTN` coherence: the explicit config field always wins; the
/// ambient only fills the default. Lane keys never depend on the
/// ambient. (The fused-ambient branch itself is exercised by the CI
/// `TOMA_ATTN=fused` leg — in-process env mutation would race parallel
/// tests.)
#[test]
fn toma_attn_override_coherence() {
    let cfg = EngineConfig::new("uvit_af", "toma", Some(0.5));
    assert_eq!(cfg.clone().with_attn(AttnMode::Fused).resolved_attn(), AttnMode::Fused);
    match std::env::var("TOMA_ATTN").as_deref() {
        Ok("fused") => {
            assert_eq!(attention::ambient(), AttnMode::Fused);
            assert_eq!(cfg.resolved_attn(), AttnMode::Fused);
        }
        Ok("materialized") | Ok("auto") => {
            assert_eq!(attention::ambient(), AttnMode::Materialized);
            assert_eq!(cfg.resolved_attn(), AttnMode::Materialized);
        }
        _ => assert_eq!(cfg.resolved_attn(), attention::ambient()),
    }
    // Ambient never re-keys: the key reflects only the field.
    assert_eq!(cfg.key(), "uvit_af:toma:0.5:tile:10+5:s50:g5");
    // Models inherit the ambient at construction.
    let info = ModelInfo::synthetic("uvit_af", 4, 2, 16, 2, 3, 5);
    assert_eq!(HostUVit::synthetic(&info, 1, 1).attn, attention::ambient());
}

/// The acceptance pin on scratch: a fused task's scratch is
/// O(Bq·Bk + Bq·dh) — independent of nq/nk — and that is what the
/// thread actually retains after running the serial fused path, far
/// below the materialized O(nq·nk) requirement for the same shape.
#[test]
fn fused_scratch_is_tile_bounded_not_logits_bounded() {
    let dh = 8usize;
    assert_eq!(
        task_scratch_elems(AttnMode::Fused, 64, 160, dh),
        task_scratch_elems(AttnMode::Fused, 4096, 4096, dh),
        "fused scratch must not scale with nq/nk"
    );
    let fused_need = BQ * dh + BQ * BK + 2 * BQ;
    assert_eq!(task_scratch_elems(AttnMode::Fused, 64, 160, dh), fused_need);

    // Run the fused path below the parallel threshold so the tasks
    // execute on this thread, then read back what the thread retains.
    // (Each #[test] runs on a fresh thread, so the scratch starts empty.)
    let (s, h, nq, nk, d) = (1usize, 1usize, 64usize, 160usize, dh);
    let (q, k, v) = qkv(0x5C, s, nq, nk, d);
    let _ = run(AttnMode::Fused, &q, &k, &v, s, nq, nk, d, h);
    assert_eq!(
        thread_scratch_len(),
        fused_need,
        "serial fused run must retain exactly the tile-sized scratch"
    );
    // A second, larger serial shape (still under the MAC threshold)
    // leaves the retained scratch unchanged — the O() claim, observed.
    let (nq2, nk2) = (96usize, 170usize);
    let (q2, k2, v2) = qkv(0x5D, s, nq2, nk2, d);
    let _ = run(AttnMode::Fused, &q2, &k2, &v2, s, nq2, nk2, d, h);
    assert_eq!(thread_scratch_len(), fused_need, "larger nq/nk must not grow fused scratch");
    assert!(
        fused_need < task_scratch_elems(AttnMode::Materialized, nq2, nk2, dh),
        "fused scratch must undercut materialized even at modest shapes"
    );
}
