//! TLB — the Theoretical Lower Bound dummy merge (Sec. 5.1).
//!
//! Approximates the maximum attainable speedup of token reduction by
//! dropping tokens outright (keep the first D) and duplicating the retained
//! features back to full length on "unmerge". No similarity computation, no
//! gather logic: pure slicing, isolating the token-count benefit.

/// Keep-first-k reducer with tile-duplication restore.
#[derive(Clone, Copy, Debug)]
pub struct TlbReducer {
    pub n: usize,
    pub k: usize,
}

impl TlbReducer {
    pub fn new(n: usize, ratio: f32) -> Self {
        let k = (((1.0 - ratio) * n as f32).round() as usize).max(1);
        TlbReducer { n, k }
    }

    pub fn merge(&self, x: &[f32], d: usize) -> Vec<f32> {
        assert_eq!(x.len(), self.n * d);
        x[..self.k * d].to_vec()
    }

    pub fn unmerge(&self, y: &[f32], d: usize) -> Vec<f32> {
        assert_eq!(y.len(), self.k * d);
        let mut out = Vec::with_capacity(self.n * d);
        while out.len() < self.n * d {
            let take = (self.n * d - out.len()).min(y.len());
            out.extend_from_slice(&y[..take]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios() {
        assert_eq!(TlbReducer::new(64, 0.5).k, 32);
        assert_eq!(TlbReducer::new(64, 0.75).k, 16);
        assert_eq!(TlbReducer::new(4, 0.99).k, 1);
    }

    #[test]
    fn merge_slices_prefix() {
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let r = TlbReducer::new(8, 0.5);
        assert_eq!(r.merge(&x, 2), &x[..8]);
    }

    #[test]
    fn unmerge_duplicates() {
        let r = TlbReducer::new(4, 0.5);
        let y = vec![1.0, 2.0, 3.0, 4.0]; // k=2, d=2
        let out = r.unmerge(&y, 2);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn roundtrip_shape() {
        let r = TlbReducer::new(10, 0.7);
        let x = vec![0.5f32; 10 * 3];
        assert_eq!(r.unmerge(&r.merge(&x, 3), 3).len(), 30);
    }
}
