//! Artifact manifest parsing (`artifacts/manifest.json`).
//!
//! The manifest is written by `python/compile/aot.py` and is the single
//! source of truth for: which HLO files exist, their parameter order
//! (model weights first, in tree-flatten order, then runtime inputs), input
//! shapes/dtypes, and the ToMA metadata (variant, ratio, regions).
//!
//! Parameters may be declared in half precision — the storage side of the
//! mixed-precision substrate (`tensor::element`). A model entry's `params`
//! list looks like:
//!
//! ```json
//! "params": [
//!   {"name": "patch.w",        "shape": [4, 128],    "dtype": "f32"},
//!   {"name": "blocks.0.qkv.w", "shape": [128, 384],  "dtype": "bf16"},
//!   {"name": "blocks.0.qkv.b", "shape": [384],       "dtype": "bf16"}
//! ]
//! ```
//!
//! `bf16`/`f16` params are streamed to the device in their declared dtype
//! (halving weight-upload and HBM bytes); runtime activations (`x_t`, `t`,
//! `cond`) stay `f32` unless the artifact was lowered otherwise.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::anyhow;
use crate::util::error::{Context, Result};
use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    Step,
    Select,
    /// Weights-only rebuild (destinations kept) — Sec. 4.3.2 split refresh.
    Weights,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    /// bfloat16 (half-precision param storage; see `tensor::element`).
    BF16,
    /// IEEE binary16.
    F16,
    S32,
    U32,
}

impl Dtype {
    /// Every dtype a manifest may declare, in the order error messages
    /// list them.
    pub const ACCEPTED: [Dtype; 5] =
        [Dtype::F32, Dtype::BF16, Dtype::F16, Dtype::S32, Dtype::U32];

    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "bf16" => Ok(Dtype::BF16),
            "f16" => Ok(Dtype::F16),
            "s32" => Ok(Dtype::S32),
            "u32" => Ok(Dtype::U32),
            _ => Err(anyhow!(
                "unknown dtype `{s}` (accepted: {})",
                Dtype::ACCEPTED.map(|d| d.as_str()).join(", ")
            )),
        }
    }

    /// Manifest spelling — `parse(d.as_str()) == Ok(d)` for every variant.
    pub fn as_str(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::BF16 => "bf16",
            Dtype::F16 => "f16",
            Dtype::S32 => "s32",
            Dtype::U32 => "u32",
        }
    }

    /// Bytes per element as stored/streamed.
    pub fn size_bytes(self) -> usize {
        match self {
            Dtype::BF16 | Dtype::F16 => 2,
            Dtype::F32 | Dtype::S32 | Dtype::U32 => 4,
        }
    }
}

impl std::fmt::Display for Dtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Shape + dtype of one runtime input or output.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// Bytes this tensor occupies as stored/streamed in its dtype.
    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype.size_bytes()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        // Errors below name the offending param so a bad manifest entry
        // is findable among hundreds of weights.
        let who = if name.is_empty() { "<unnamed>" } else { name.as_str() };
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("param `{who}`: missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("param `{who}`: bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .str_field("dtype")
            .map_err(|e| anyhow!("param `{who}`: {e}"))
            .and_then(|s| Dtype::parse(s).map_err(|e| anyhow!("param `{who}`: {e}")))?;
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// One entry of the artifact index.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: ArtifactKind,
    pub model: String,
    pub file: String,
    pub kernel_impl: String,
    /// Token-reduction variant for steps ("baseline", "toma", ...).
    pub variant: Option<String>,
    /// Selection mode for selects ("tile", "stripe", "global", "random").
    pub mode: Option<String>,
    pub ratio: Option<f64>,
    pub regions: usize,
    pub region_mode: Option<String>,
    /// Weight-parameter names this artifact consumes, in lowering order.
    /// Empty means "all model parameters" (legacy manifests).
    pub params: Vec<String>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Model metadata (shapes + parameter inventory).
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub kind: String, // "uvit" | "dit"
    pub latent_hw: usize,
    pub channels: usize,
    pub dim: usize,
    pub heads: usize,
    pub txt_len: usize,
    pub txt_dim: usize,
    pub batch: usize,
    pub tokens: usize,
    /// Parameter order as lowered (names match the weights npz).
    pub params: Vec<TensorSpec>,
}

impl ModelInfo {
    /// Minimal in-memory model description for artifact-free host serving
    /// (the scheduler's synthetic-model tests and the serve_sweep bench):
    /// patch size 1, so tokens = grid^2; CFG batch of 2.
    pub fn synthetic(
        name: &str,
        grid: usize,
        channels: usize,
        dim: usize,
        heads: usize,
        txt_len: usize,
        txt_dim: usize,
    ) -> ModelInfo {
        ModelInfo {
            name: name.to_string(),
            kind: "uvit".to_string(),
            latent_hw: grid,
            channels,
            dim,
            heads,
            txt_len,
            txt_dim,
            batch: 2,
            tokens: grid * grid,
            params: vec![],
        }
    }

    pub fn grid(&self) -> usize {
        (self.tokens as f64).sqrt() as usize
    }

    pub fn latent_len(&self) -> usize {
        self.batch * self.channels * self.latent_hw * self.latent_hw
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub tau: f64,
    pub dest_every: u64,
    pub weight_every: u64,
    pub models: BTreeMap<String, ModelInfo>,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;

        let mut models = BTreeMap::new();
        for (name, m) in j
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing models"))?
        {
            let params = m
                .get("params")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("model {name} missing params"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    kind: m.str_field("kind").map_err(|e| anyhow!("{e}"))?.into(),
                    latent_hw: m.num_field("latent_hw").map_err(|e| anyhow!("{e}"))? as usize,
                    channels: m.num_field("channels").map_err(|e| anyhow!("{e}"))? as usize,
                    dim: m.num_field("dim").map_err(|e| anyhow!("{e}"))? as usize,
                    heads: m.num_field("heads").map_err(|e| anyhow!("{e}"))? as usize,
                    txt_len: m.num_field("txt_len").map_err(|e| anyhow!("{e}"))? as usize,
                    txt_dim: m.num_field("txt_dim").map_err(|e| anyhow!("{e}"))? as usize,
                    batch: m.num_field("batch").map_err(|e| anyhow!("{e}"))? as usize,
                    tokens: m.num_field("tokens").map_err(|e| anyhow!("{e}"))? as usize,
                    params,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let name = a.str_field("name").map_err(|e| anyhow!("{e}"))?.to_string();
            let kind = match a.str_field("kind").map_err(|e| anyhow!("{e}"))? {
                "step" => ArtifactKind::Step,
                "select" => ArtifactKind::Select,
                "weights" => ArtifactKind::Weights,
                other => return Err(anyhow!("unknown artifact kind {other}")),
            };
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactEntry {
                    name: name.clone(),
                    kind,
                    model: a.str_field("model").map_err(|e| anyhow!("{e}"))?.into(),
                    file: a.str_field("file").map_err(|e| anyhow!("{e}"))?.into(),
                    kernel_impl: a
                        .get("kernel_impl")
                        .and_then(Json::as_str)
                        .unwrap_or("jnp")
                        .into(),
                    variant: a.get("variant").and_then(Json::as_str).map(String::from),
                    mode: a.get("mode").and_then(Json::as_str).map(String::from),
                    ratio: a.get("ratio").and_then(Json::as_f64),
                    regions: a.get("regions").and_then(Json::as_usize).unwrap_or(1),
                    region_mode: a
                        .get("region_mode")
                        .and_then(Json::as_str)
                        .map(String::from),
                    params: a
                        .get("params")
                        .and_then(Json::as_arr)
                        .map(|arr| {
                            arr.iter()
                                .filter_map(Json::as_str)
                                .map(String::from)
                                .collect()
                        })
                        .unwrap_or_default(),
                    inputs,
                    outputs,
                },
            );
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            tau: j.num_field("tau").map_err(|e| anyhow!("{e}"))?,
            dest_every: j.num_field("dest_every").map_err(|e| anyhow!("{e}"))? as u64,
            weight_every: j.num_field("weight_every").map_err(|e| anyhow!("{e}"))? as u64,
            models,
            artifacts,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name} not in manifest"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))
    }

    /// Step artifact name for (model, variant, ratio).
    pub fn step_name(&self, model: &str, variant: &str, ratio: Option<f64>) -> Result<String> {
        if variant == "baseline" {
            return Ok(format!("{model}_step_baseline"));
        }
        let r = ratio.ok_or_else(|| anyhow!("variant {variant} needs a ratio"))?;
        let tag = format!("r{:02}", (r * 100.0).round() as u32);
        // toma_tile carries its region count in the name; find by scan.
        let prefix = format!("{model}_step_{variant}_{tag}");
        if self.artifacts.contains_key(&prefix) {
            return Ok(prefix);
        }
        self.artifacts
            .keys()
            .find(|k| k.starts_with(&prefix))
            .cloned()
            .ok_or_else(|| anyhow!("no artifact for {model}/{variant}/{tag}"))
    }

    /// Select artifact name for (model, mode, ratio[, regions]).
    pub fn select_name(
        &self,
        model: &str,
        mode: &str,
        ratio: f64,
        regions: Option<usize>,
    ) -> Result<String> {
        let tag = format!("r{:02}", (ratio * 100.0).round() as u32);
        let candidates: Vec<&String> = self
            .artifacts
            .keys()
            .filter(|k| k.starts_with(&format!("{model}_select_{mode}_{tag}")))
            .collect();
        match regions {
            Some(p) => {
                let exact = format!("{model}_select_{mode}_{tag}_p{p}");
                if self.artifacts.contains_key(&exact) {
                    Ok(exact)
                } else {
                    candidates
                        .first()
                        .map(|s| s.to_string())
                        .ok_or_else(|| anyhow!("no select artifact {exact}"))
                }
            }
            None => candidates
                .first()
                .map(|s| s.to_string())
                .ok_or_else(|| anyhow!("no select artifact for {model}/{mode}/{tag}")),
        }
    }

    /// Weights-only artifact paired with a select artifact, if present.
    pub fn weights_name_for_select(&self, select_name: &str) -> Option<String> {
        let w = select_name.replace("_select_", "_weights_");
        self.artifacts.contains_key(&w).then_some(w)
    }

    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    pub fn weights_path(&self, model: &str) -> PathBuf {
        self.dir.join("weights").join(format!("{model}.npz"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest_json() -> String {
        r#"{
 "tau": 0.1, "dest_every": 10, "weight_every": 5,
 "models": {
  "uvit_xs": {"kind": "uvit", "latent_hw": 16, "channels": 4, "patch": 1,
    "dim": 128, "heads": 4, "txt_len": 16, "txt_dim": 64, "batch": 2,
    "tokens": 256, "depth": 4,
    "params": [{"name": "patch.w", "shape": [4, 128], "dtype": "f32"}]}
 },
 "artifacts": [
  {"name": "uvit_xs_step_baseline", "kind": "step", "model": "uvit_xs",
   "file": "uvit_xs_step_baseline.hlo.txt", "kernel_impl": "jnp",
   "variant": "baseline", "ratio": null, "regions": 1,
   "inputs": [{"name": "x_t", "shape": [2, 4, 16, 16], "dtype": "f32"}],
   "outputs": [{"shape": [2, 4, 16, 16], "dtype": "f32"}]},
  {"name": "uvit_xs_step_toma_r50", "kind": "step", "model": "uvit_xs",
   "file": "f.hlo.txt", "kernel_impl": "jnp", "variant": "toma",
   "ratio": 0.5, "regions": 1, "inputs": [], "outputs": []},
  {"name": "uvit_xs_select_tile_r50_p16", "kind": "select",
   "model": "uvit_xs", "file": "s.hlo.txt", "kernel_impl": "jnp",
   "mode": "tile", "ratio": 0.5, "regions": 16,
   "inputs": [], "outputs": []}
 ]
}"#
        .to_string()
    }

    fn load_fake() -> Manifest {
        let dir = std::env::temp_dir().join(format!("toma_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), fake_manifest_json()).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn parses_models_and_artifacts() {
        let m = load_fake();
        assert_eq!(m.tau, 0.1);
        assert_eq!(m.dest_every, 10);
        let model = m.model("uvit_xs").unwrap();
        assert_eq!(model.tokens, 256);
        assert_eq!(model.grid(), 16);
        assert_eq!(model.params.len(), 1);
        let a = m.artifact("uvit_xs_step_baseline").unwrap();
        assert_eq!(a.kind, ArtifactKind::Step);
        assert_eq!(a.inputs[0].shape, vec![2, 4, 16, 16]);
        assert_eq!(a.inputs[0].elements(), 2048);
    }

    #[test]
    fn step_name_resolution() {
        let m = load_fake();
        assert_eq!(
            m.step_name("uvit_xs", "baseline", None).unwrap(),
            "uvit_xs_step_baseline"
        );
        assert_eq!(
            m.step_name("uvit_xs", "toma", Some(0.5)).unwrap(),
            "uvit_xs_step_toma_r50"
        );
        assert!(m.step_name("uvit_xs", "toma", Some(0.25)).is_err());
    }

    #[test]
    fn select_name_resolution() {
        let m = load_fake();
        assert_eq!(
            m.select_name("uvit_xs", "tile", 0.5, Some(16)).unwrap(),
            "uvit_xs_select_tile_r50_p16"
        );
        // Region-less lookup falls back to the first matching candidate.
        assert_eq!(
            m.select_name("uvit_xs", "tile", 0.5, None).unwrap(),
            "uvit_xs_select_tile_r50_p16"
        );
        assert!(m.select_name("uvit_xs", "stripe", 0.5, None).is_err());
    }

    #[test]
    fn missing_model_errors() {
        let m = load_fake();
        assert!(m.model("nope").is_err());
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn dtype_parse_round_trips_all_accepted() {
        for d in Dtype::ACCEPTED {
            assert_eq!(Dtype::parse(d.as_str()).unwrap(), d);
            assert_eq!(format!("{d}"), d.as_str());
        }
        assert_eq!(Dtype::BF16.size_bytes(), 2);
        assert_eq!(Dtype::F16.size_bytes(), 2);
        assert_eq!(Dtype::F32.size_bytes(), 4);
    }

    #[test]
    fn half_precision_params_parse_and_halve_bytes() {
        let dir = std::env::temp_dir().join(format!(
            "toma_manifest_bf16_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let json = fake_manifest_json().replace(
            r#"[{"name": "patch.w", "shape": [4, 128], "dtype": "f32"}]"#,
            r#"[{"name": "patch.w", "shape": [4, 128], "dtype": "f32"},
                {"name": "blocks.0.qkv.w", "shape": [128, 384], "dtype": "bf16"},
                {"name": "blocks.0.mlp1.w", "shape": [128, 512], "dtype": "f16"}]"#,
        );
        std::fs::write(dir.join("manifest.json"), json).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let model = m.model("uvit_xs").unwrap();
        assert_eq!(model.params.len(), 3);
        assert_eq!(model.params[1].dtype, Dtype::BF16);
        assert_eq!(model.params[2].dtype, Dtype::F16);
        // The declared storage halves the streamed bytes vs f32.
        assert_eq!(model.params[1].bytes(), 128 * 384 * 2);
        assert_eq!(model.params[0].bytes(), 4 * 128 * 4);
    }

    #[test]
    fn bad_dtype_error_names_param_and_lists_accepted() {
        let dir = std::env::temp_dir().join(format!(
            "toma_manifest_baddt_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let json = fake_manifest_json().replace(
            r#"{"name": "patch.w", "shape": [4, 128], "dtype": "f32"}"#,
            r#"{"name": "patch.w", "shape": [4, 128], "dtype": "f64"}"#,
        );
        std::fs::write(dir.join("manifest.json"), json).unwrap();
        let err = Manifest::load(&dir).err().expect("must fail").to_string();
        assert!(err.contains("patch.w"), "error must name the param: {err}");
        assert!(err.contains("f64"), "error must quote the bad dtype: {err}");
        assert!(
            err.contains("bf16") && err.contains("f16") && err.contains("u32"),
            "error must list accepted dtypes: {err}"
        );
    }
}
