//! Substrate utilities: RNG, statistics, JSON, CLI parsing, property
//! tests, poison-tolerant locking, and the crate-wide error plumbing.

pub mod argparse;
pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;

pub use rng::Pcg64;
pub use sync::lock_unpoisoned;
