//! Request / result types for the serving coordinator.

use crate::tensor::attention::{self, AttnMode};
use crate::tensor::element::StorageDtype;
use crate::toma::plan::ReuseSchedule;

/// Engine configuration: one engine per (model, variant, ratio, schedule).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub model: String,
    /// "baseline", "toma", "toma_stripe", "toma_tile", "toma_once",
    /// "toma_pinv", "toma_colsm", "tlb", "tome", "tofu", "todo".
    pub variant: String,
    pub ratio: Option<f64>,
    pub steps: usize,
    /// Classifier-free guidance weight.
    pub guidance: f32,
    pub schedule: ReuseSchedule,
    /// Destination-selection mode: "tile" | "stripe" | "global" | "random".
    pub select_mode: String,
    /// Weight-panel storage dtype for this engine's model. The default
    /// (`f32`) is bit-exact with the pre-dtype substrate and keeps the
    /// historical [`EngineConfig::key`] unchanged; `bf16`/`f16` halve the
    /// resident weight bytes at a small accuracy cost and key into their
    /// own lanes/cohorts (latents are storage-dependent, so mixing
    /// storages in one cohort would break plan compatibility).
    pub storage: StorageDtype,
    /// Opt-in plan-cache tolerance (PR 8). `None` (the default) disables
    /// the fingerprinted merge-plan cache entirely and keeps the
    /// historical [`EngineConfig::key`] unchanged; `Some(t)` enables
    /// similarity-thresholded plan reuse at refresh boundaries and keys
    /// its own lanes, exactly like non-f32 storage — a tolerant lane
    /// never shares plans with the bit-exact default path. `Some(0.0)`
    /// is exact-fingerprint reuse (bit-identical by construction).
    pub plan_tolerance: Option<f64>,
    /// SDPA implementation for this engine's host model (PR 9).
    /// `Materialized` (the default) is bit-exact and keeps the historical
    /// [`EngineConfig::key`] unchanged; `Fused` runs online-softmax
    /// streaming attention — within a pinned ≤1e-5 relative envelope but
    /// NOT bit-identical (the reduction is reordered) — and keys its own
    /// lanes/cohorts, exactly like non-f32 storage.
    pub attn: AttnMode,
}

impl EngineConfig {
    pub fn new(model: &str, variant: &str, ratio: Option<f64>) -> Self {
        EngineConfig {
            model: model.to_string(),
            variant: variant.to_string(),
            ratio,
            steps: 50,
            guidance: 5.0,
            schedule: ReuseSchedule::default(),
            select_mode: "tile".to_string(),
            storage: StorageDtype::F32,
            plan_tolerance: None,
            attn: AttnMode::Materialized,
        }
    }

    /// Builder: select the SDPA implementation.
    pub fn with_attn(mut self, attn: AttnMode) -> Self {
        self.attn = attn;
        self
    }

    /// Builder: select the weight-panel storage dtype.
    pub fn with_storage(mut self, storage: StorageDtype) -> Self {
        self.storage = storage;
        self
    }

    /// Builder: enable the fingerprinted plan cache at `tolerance`.
    pub fn with_plan_tolerance(mut self, tolerance: f64) -> Self {
        self.plan_tolerance = Some(tolerance);
        self
    }

    /// The effective plan-cache tolerance: the config field, or — when
    /// unset — the `TOMA_PLAN_TOLERANCE` ambient (read at engine/cohort
    /// construction, mirroring `FaultInjector::from_env`, so [`key`] stays
    /// purely field-driven and ambient smoke runs don't re-key lanes).
    ///
    /// [`key`]: EngineConfig::key
    pub fn resolved_plan_tolerance(&self) -> Option<f64> {
        self.plan_tolerance.or_else(|| {
            std::env::var("TOMA_PLAN_TOLERANCE")
                .ok()
                .and_then(|s| s.trim().parse::<f64>().ok())
        })
    }

    /// The effective attention mode: the config field, or — when it is
    /// the materialized default — the `TOMA_ATTN` ambient (read at
    /// engine/cohort construction, mirroring
    /// [`resolved_plan_tolerance`](EngineConfig::resolved_plan_tolerance),
    /// so [`key`](EngineConfig::key) stays purely field-driven and the CI
    /// `TOMA_ATTN=fused` smoke leg doesn't re-key lanes).
    pub fn resolved_attn(&self) -> AttnMode {
        match self.attn {
            AttnMode::Fused => AttnMode::Fused,
            AttnMode::Materialized => attention::ambient(),
        }
    }

    /// Does this variant consume ToMA merge weights at runtime?
    pub fn needs_plan(&self) -> bool {
        self.variant.starts_with("toma")
    }

    /// Cache / batch key. Every field that changes what a lane's engine
    /// or cohort backend computes must appear here — a request with a
    /// different step count or guidance weight is *not* plan-compatible
    /// with an existing lane and must get its own. Floats use the
    /// shortest-roundtrip `Display` form, so distinct values never
    /// collide in the key. The storage dtype appears only when it is not
    /// the f32 default, so pre-dtype cohort keys (and any baselines keyed
    /// on them) are unchanged; likewise the plan tolerance appears only
    /// when explicitly set, so tolerant lanes are segregated from the
    /// bit-exact default path without perturbing historical keys. The
    /// attention mode follows the same rule: only `fused` appends a
    /// suffix (`:attn-fused`), because fused latents are numerically
    /// different and must never share a cohort with materialized ones.
    pub fn key(&self) -> String {
        let storage = match self.storage {
            StorageDtype::F32 => String::new(),
            other => format!(":dt{other}"),
        };
        let tolerance = match self.plan_tolerance {
            None => String::new(),
            Some(t) => format!(":tol{t}"),
        };
        let attn = match self.attn {
            AttnMode::Materialized => String::new(),
            AttnMode::Fused => ":attn-fused".to_string(),
        };
        format!(
            "{}:{}:{}:{}:{}+{}:s{}:g{}{}{}{}",
            self.model,
            self.variant,
            self.ratio.map(|r| r.to_string()).unwrap_or_default(),
            self.select_mode,
            self.schedule.dest_every,
            self.schedule.weight_every,
            self.steps,
            self.guidance,
            storage,
            tolerance,
            attn
        )
    }
}

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: String,
    pub seed: u64,
    /// Record per-step destination sets (Fig. 4) and plan stats.
    pub trace: bool,
    /// Admission deadline (seconds from submission): the micro-batching
    /// scheduler sheds the request instead of serving it late. `None`
    /// falls back to the lane's `BatchPolicy::deadline_s`.
    pub deadline_s: Option<f64>,
}

impl GenRequest {
    pub fn new(prompt: &str, seed: u64) -> Self {
        GenRequest {
            prompt: prompt.to_string(),
            seed,
            trace: false,
            deadline_s: None,
        }
    }

    /// Attach an admission deadline (seconds from submission).
    pub fn with_deadline(mut self, deadline_s: f64) -> Self {
        self.deadline_s = Some(deadline_s);
        self
    }
}

/// Timing + cache statistics for one generation.
#[derive(Clone, Debug, Default)]
pub struct GenStats {
    pub total_s: f64,
    pub select_s: f64,
    pub step_s: f64,
    pub host_s: f64,
    pub steps: usize,
    pub select_calls: usize,
    pub weight_refreshes: usize,
    pub plan_reuses: usize,
    /// RefreshAll boundaries served from the fingerprinted plan cache
    /// (PR 8) instead of running selection. Always 0 when the cache is
    /// disabled (plan tolerance unset).
    pub plan_cache_hits: usize,
    /// RefreshAll boundaries that probed the cache and ran selection.
    pub plan_cache_misses: usize,
    /// Largest cohort this request was batched with (micro-batching
    /// scheduler only; 0 for the per-request engines).
    pub cohort_size: usize,
}

/// Result of one generation.
#[derive(Clone, Debug)]
pub struct GenResult {
    /// Final denoised latent for the conditional row, (C, H, W) flattened.
    pub latent: Vec<f32>,
    pub stats: GenStats,
    /// Per-step global destination-token sets (only when trace=true).
    pub dest_trace: Vec<Vec<usize>>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn needs_plan_per_variant() {
        for v in ["toma", "toma_stripe", "toma_tile", "toma_once", "toma_pinv"] {
            assert!(EngineConfig::new("m", v, Some(0.5)).needs_plan(), "{v}");
        }
        for v in ["baseline", "tlb", "tome", "tofu", "todo"] {
            assert!(!EngineConfig::new("m", v, Some(0.5)).needs_plan(), "{v}");
        }
    }

    #[test]
    fn deadline_builder_sets_field() {
        let r = GenRequest::new("p", 1);
        assert!(r.deadline_s.is_none());
        let r = r.with_deadline(0.25);
        assert_eq!(r.deadline_s, Some(0.25));
    }

    #[test]
    fn key_distinguishes_configs() {
        let a = EngineConfig::new("uvit_s", "toma", Some(0.5));
        let mut b = a.clone();
        b.ratio = Some(0.25);
        assert_ne!(a.key(), b.key());
        let mut c = a.clone();
        c.schedule.dest_every = 1;
        assert_ne!(a.key(), c.key());
        // steps/guidance change the lane's engine: distinct keys too.
        let mut d = a.clone();
        d.steps = 25;
        assert_ne!(a.key(), d.key());
        let mut e = a.clone();
        e.guidance = 7.5;
        assert_ne!(a.key(), e.key());
        // Shortest-roundtrip float formatting: close values don't collide.
        let mut f = a.clone();
        f.guidance = 5.001;
        assert_ne!(a.key(), f.key());
    }

    #[test]
    fn default_storage_keeps_historical_key() {
        use crate::tensor::element::StorageDtype;
        let a = EngineConfig::new("uvit_s", "toma", Some(0.5));
        assert_eq!(a.storage, StorageDtype::F32);
        // The exact PR 2 key format: no dtype suffix for the default.
        assert_eq!(a.key(), "uvit_s:toma:0.5:tile:10+5:s50:g5");
        let b = a.clone().with_storage(StorageDtype::Bf16);
        assert_eq!(b.key(), "uvit_s:toma:0.5:tile:10+5:s50:g5:dtbf16");
        assert_ne!(
            b.key(),
            a.clone().with_storage(StorageDtype::F16).key(),
            "each storage dtype gets its own cohort"
        );
    }

    #[test]
    fn plan_tolerance_keys_its_own_lanes() {
        let a = EngineConfig::new("uvit_s", "toma", Some(0.5));
        assert!(a.plan_tolerance.is_none());
        // Unset tolerance: the exact historical key, no suffix.
        assert_eq!(a.key(), "uvit_s:toma:0.5:tile:10+5:s50:g5");
        let b = a.clone().with_plan_tolerance(0.0);
        assert_eq!(b.key(), "uvit_s:toma:0.5:tile:10+5:s50:g5:tol0");
        let c = a.clone().with_plan_tolerance(0.05);
        assert_eq!(c.key(), "uvit_s:toma:0.5:tile:10+5:s50:g5:tol0.05");
        assert_ne!(b.key(), c.key(), "each tolerance gets its own lanes");
        // Tolerance and storage suffixes compose.
        let d = a.clone().with_storage(StorageDtype::Bf16).with_plan_tolerance(0.0);
        assert_eq!(d.key(), "uvit_s:toma:0.5:tile:10+5:s50:g5:dtbf16:tol0");
    }

    #[test]
    fn fused_attn_keys_its_own_lanes() {
        use crate::tensor::attention::AttnMode;
        let a = EngineConfig::new("uvit_s", "toma", Some(0.5));
        assert_eq!(a.attn, AttnMode::Materialized);
        // Materialized default: the exact historical key, no suffix.
        assert_eq!(a.key(), "uvit_s:toma:0.5:tile:10+5:s50:g5");
        let b = a.clone().with_attn(AttnMode::Fused);
        assert_eq!(b.key(), "uvit_s:toma:0.5:tile:10+5:s50:g5:attn-fused");
        // Composes after the storage and tolerance suffixes.
        let c = a
            .clone()
            .with_storage(StorageDtype::Bf16)
            .with_plan_tolerance(0.05)
            .with_attn(AttnMode::Fused);
        assert_eq!(c.key(), "uvit_s:toma:0.5:tile:10+5:s50:g5:dtbf16:tol0.05:attn-fused");
    }

    #[test]
    fn resolved_attn_prefers_explicit_field() {
        use crate::tensor::attention::AttnMode;
        let a = EngineConfig::new("uvit_s", "toma", Some(0.5));
        let b = a.clone().with_attn(AttnMode::Fused);
        assert_eq!(b.resolved_attn(), AttnMode::Fused);
        // The ambient fallback is covered by the CI TOMA_ATTN=fused leg
        // (env mutation in-process would race parallel tests); with no
        // env and no field it resolves to the materialized default.
        if std::env::var("TOMA_ATTN").is_err() {
            assert_eq!(a.resolved_attn(), AttnMode::Materialized);
        }
    }

    #[test]
    fn resolved_tolerance_prefers_explicit_field() {
        let a = EngineConfig::new("uvit_s", "toma", Some(0.5));
        let b = a.clone().with_plan_tolerance(0.25);
        assert_eq!(b.resolved_plan_tolerance(), Some(0.25));
        // The ambient fallback is covered by the CI TOMA_PLAN_TOLERANCE=0
        // pass (env mutation in-process would race parallel tests); with
        // no env and no field it resolves to None on a default test run.
        if std::env::var("TOMA_PLAN_TOLERANCE").is_err() {
            assert_eq!(a.resolved_plan_tolerance(), None);
        }
    }
}
