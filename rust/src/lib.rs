//! # ToMA — Token Merge with Attention for Diffusion Models
//!
//! Full-system reproduction of *ToMA: Token Merge with Attention for
//! Diffusion Models* (ICML 2025) as a three-layer Rust + JAX + Pallas stack.
//!
//! This crate is **Layer 3**: the serving coordinator that owns the
//! denoising loop, dynamic request batching, and — the heart of the paper's
//! Sec. 4.3 — the *merge-plan cache* that decides when destination tokens
//! and merge weights are recomputed versus reused. Model compute runs
//! through AOT-compiled XLA artifacts (see `runtime`); Python never executes
//! at serve time.
//!
//! Module map (see DESIGN.md for the experiment index):
//!
//! * [`toma`] — host reference of the paper's operators: facility-location
//!   selection (incremental-gain lazy greedy since PR 1), attention merge,
//!   transpose/pinv unmerge, region layouts.
//! * [`baselines`] — ToMeSD / ToFu / ToDo / TLB reimplementations.
//! * [`coordinator`] — engine, plan cache, metrics (latency histograms
//!   with p50/p95/p99), and the two serving front-ends. Since PR 4 both
//!   are thin instantiations of [`coordinator::frontend`]'s generic
//!   `LaneFrontEnd<J: LaneJob>` — one shared implementation of the lane
//!   map, bounded queues with submit/try_submit backpressure, deadline
//!   shedding, generation-checked dead-lane evict/respawn and the
//!   lane-lifecycle counters (`lane_spawned` / `lane_respawned` /
//!   `lane_evicted` / `shed_deadline` / `rejected_backpressure`):
//!   the per-request `Server` (one engine per worker thread) and — since
//!   PR 2 — [`coordinator::scheduler`]: step-level continuous
//!   micro-batching. Plan-compatible requests form *cohorts* that advance
//!   through batched denoising steps sharing one `PlanSlot`
//!   (selection/weights amortize across the batch), join mid-flight at
//!   refresh boundaries, leave on completion, and are governed by a
//!   `LanePolicy` — the static `BatchPolicy`, or the PR 4
//!   `AdaptivePolicy` deriving each lane's formation window and batch cap
//!   from observed inter-arrival times and a p99 target
//!   (`--policy static|adaptive`), with overload feedback from a per-lane
//!   exponentially-decayed served tail (`DecayedTail`, PR 5 — no shrink
//!   floor needed). Batched latents are bit-identical to
//!   per-request ones (`tests/scheduler_equivalence.rs`); the `frontend`
//!   seam is where a future PJRT cohort backend plugs in. Since PR 6 the
//!   shared substrate is *supervised*: worker panics are caught at the
//!   lane unwind boundary and surfaced as retryable error completions
//!   (never a dropped sender), dead lanes respawn under exponential
//!   backoff with a crash-storm circuit breaker (`lane_unhealthy` →
//!   fail-fast, half-open probes), poison requests are quarantined after
//!   K consecutive lane deaths while innocent cohort members are
//!   transparently re-run bit-identically (`RetryPolicy`), and graceful
//!   drain answers queued jobs with explicit "shutting down"
//!   completions. The deterministic chaos substrate behind it is
//!   [`coordinator::fault`] (`TOMA_FAULTS`, `FaultPlan`:
//!   panic/slow/error/stall at the `server.step` / `scheduler.step`
//!   probes), driving `tests/chaos.rs` against both front-ends. Since
//!   PR 7 the stack is *observable* ([`coordinator::trace`]): an
//!   optional `Tracer` records compact spans (submit / queue-wait /
//!   formation / select / refresh / step / retry / fault) onto a
//!   lock-free overwrite-oldest ring with exact dropped-span
//!   accounting, exported as OTLP-shaped JSON or a delta+RLE binary
//!   (`toma-serve serve --trace`, inspected by `toma-serve trace`);
//!   the default tracing-off path is bit-identical. An always-on
//!   per-lane EWMA z-score detector (`AnomalyDetector`: step-latency /
//!   queue-depth / retry-rate channels) raises `lane_degrading` before
//!   cumulative p99 moves — control loops consume its `AnomalyFlags`
//!   or `DecayedTail`, never the cumulative histograms. Since PR 8
//!   refreshes are *memoized* ([`coordinator::plan_cache`]): an opt-in
//!   fingerprinted `PlanCache` per lane sketches each `RefreshAll` input
//!   with seeded random projections ([`toma::fingerprint`]) and
//!   downgrades the refresh to a cache install on a match within the
//!   configured tolerance (`EngineConfig::plan_tolerance` /
//!   `--plan-tolerance` / `TOMA_PLAN_TOLERANCE`), skipping selection
//!   entirely — within a request, across cohort admissions, and across
//!   same-seed request families on one lane. Non-default tolerances key
//!   their own lanes, the default path stays bit-exact, and
//!   `tolerance = 0` is exact-sketch reuse, bit-identical by
//!   construction (`tests/scheduler_equivalence.rs`); hit / miss /
//!   evict counts flow into `PlanStats`, per-lane `plan[...]` counters,
//!   `cache-hit`/`cache-miss` spans and the anomaly detector's fourth
//!   `cache-miss` channel.
//! * [`runtime`] — PJRT client, artifact registry, weight store. The
//!   XLA-backed layer sits behind the `pjrt` cargo feature; the default
//!   build compiles same-API pure-Rust stubs, so no XLA toolchain is
//!   needed to build, test, or run the host benches.
//! * [`diffusion`] — DDIM / Euler samplers and noise schedules.
//! * [`model`] — pure-Rust UVitLite forward (cross-validation substrate),
//!   with multi-head attention lowered onto [`tensor::attention`].
//!   `HostUVit::forward_batch` is the scheduler's batch-folded step path
//!   (one GEMM per linear layer across the whole cohort, attention fanned
//!   out per (sample, head) — per (sample, head, q-block) on the fused
//!   path); `model::Linear` caches its packed Bᵀ panels
//!   at construction — since PR 3 in a configurable storage dtype
//!   (`EngineConfig::storage`: f32 default, or bf16/f16 which halve the
//!   resident weight bytes) — so step weights are never repacked per call.
//! * [`gpucost`] — per-GPU roofline model regenerating the paper's latency
//!   tables on hardware we do not have.
//! * [`quality`] — DINO/CLIP/FID proxy metrics.
//! * [`tensor`] — the host kernel substrate: [`tensor::pool`] (persistent
//!   worker pool + scoped parallel-for), [`tensor::element`] (sealed
//!   storage-dtype abstraction: f32 / bf16 / f16 with exact u16 bit
//!   conversions and widening loads; `StorageDtype` is the runtime
//!   selector), [`tensor::kernel`] (the PR 5 pluggable microkernel seam:
//!   a sealed `MicroKernel` trait with the scalar reference loops and
//!   explicit AVX2+FMA `std::arch` kernels — hand-vectorized bf16/f16
//!   widening loads, 2x4 register tile — behind once-per-process runtime
//!   dispatch with a `TOMA_KERNEL=scalar|auto` override; f32 results are
//!   bit-identical under every dispatch; since PR 10 the seam also
//!   carries vectorized transcendentals, `exp_body`/`exp_sub_sum` — one
//!   polynomial exp shared by the scalar and SIMD arms, bitwise
//!   dispatch-identical, envelope-bounded vs `f32::exp`),
//!   [`tensor::gemm`] (blocked, register-tiled, multithreaded GEMM
//!   lowered onto that seam, generic over each operand's storage element
//!   and accumulating in f32, with the seed's scalar loop nests kept as
//!   `gemm::scalar` references, `gemm::Panels` as the runtime-dtype
//!   dispatch, and — since PR 10 — `gemm::Epilogue`: bias / bias+gelu /
//!   bias+silu applied per output chunk at write-back, bitwise identical
//!   to the two-pass schedule it replaces and default-on in
//!   `model::Linear`), [`tensor::ops`] (public kernel surface: GEMMs —
//!   including the dtype-parameterized `matmul_e`/`matmul_at_e` — row and
//!   tiled column softmax over the seam's `row_max`/`scale` primitives,
//!   parallel row ops), and — since PR 9 — [`tensor::attention`]:
//!   multi-head SDPA with two implementations behind
//!   `EngineConfig::attn` / `--attn` /
//!   `TOMA_ATTN`. `materialized` (default) is the bit-exact three-pass
//!   reference; `fused` is online-softmax streaming tiles on the
//!   microkernel seam (`row_max`/`scale`/`axpy`/`exp_sub_sum` fused
//!   primitives, hand-vectorized in the AVX2 arm) — `O(Bq·Bk + Bq·dh)`
//!   scratch per task instead of materializing `O(nq·nk)` logits, NOT
//!   bit-identical to materialized (reduction reorder + poly exp; pinned
//!   ≤1e-5 relative envelope) but still dispatch- and fold-invariant,
//!   keying its own lanes (`:attn-fused`).
//! * [`util`], [`workload`], [`report`], [`bench`] — substrates
//!   (`util::error` is the crate's dependency-free `anyhow` stand-in;
//!   `bench::Runner` understands `--quick` and `--json <path>`, and
//!   `bench::diff` + `toma-serve bench-diff` gate CI on median
//!   regressions between runs).

// The `pjrt` feature selects the XLA-backed runtime modules, which need the
// vendored `xla` crate in [dependencies]. Until that dependency lands (see
// ROADMAP.md "Open items"), fail fast with one clear message instead of a
// page of unresolved-import errors. Delete this guard when wiring `xla` in.
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires the vendored `xla` crate: add it to \
     [dependencies] in rust/Cargo.toml and remove this guard (ROADMAP.md)"
);

pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod diffusion;
pub mod gpucost;
pub mod model;
pub mod quality;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod toma;
pub mod util;
pub mod workload;

/// Repo-relative default artifact directory (`make artifacts` output).
pub fn default_artifact_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("TOMA_ARTIFACTS") {
        return dir.into();
    }
    let mut p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    p.push("artifacts");
    p
}
