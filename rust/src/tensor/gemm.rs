//! Blocked, register-tiled, multithreaded GEMM — the parallel substrate
//! behind `tensor::ops::{matmul, matmul_bt, matmul_at, bmm}`.
//!
//! Organization (GPU-shaped-on-CPU, per the paper's thesis that merge must
//! be dense matrix work):
//!
//! * All products are lowered to one kernel shape, `C += A · Bᵀ` with both
//!   operands row-major — every inner loop is then a contiguous dot
//!   product. `matmul` packs `B` into `Bᵀ` panels first (a (k x n) →
//!   (n x k) blocked transpose), `matmul_at` packs `A`.
//! * The kernel is tiled three ways: `KC`-deep k-panels (operand panel
//!   fits L1/L2), `JB`-wide column tiles (the `Bᵀ` panel is reused across
//!   every row of the block), and a 1x4 register tile (`dot4`) whose
//!   unrolled-by-8 inner loops are written with exact-size slices so LLVM
//!   autovectorizes them.
//! * Work is split over the M dimension across the [`super::pool`] worker
//!   pool; each worker owns a disjoint row-block of `C`, so no locks and
//!   no false sharing on the hot path.
//!
//! `scalar` keeps the seed's naive loop nests as the reference
//! implementation the property tests compare against.

use super::pool;

/// k-panel depth: one A-row segment (KC floats) + a JB x KC B-panel stay
/// resident in L1/L2 while the panel is swept.
const KC: usize = 256;
/// Column-tile width of C (rows of Bᵀ reused per panel sweep).
const JB: usize = 64;
/// Below this many multiply-adds the dispatch overhead beats parallelism.
/// Shared with the model layer's attention dispatch so the serial/parallel
/// crossover points stay in sync.
pub(crate) const PAR_MIN_MACS: usize = 1 << 17;

/// Contiguous dot product, 8-wide accumulators (autovectorizes).
#[inline(always)]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n8 = a.len() / 8 * 8;
    let mut acc = [0.0f32; 8];
    let mut i = 0;
    while i < n8 {
        let x = &a[i..i + 8];
        let y = &b[i..i + 8];
        for l in 0..8 {
            acc[l] += x[l] * y[l];
        }
        i += 8;
    }
    let mut s = 0.0f32;
    for l in 0..8 {
        s += acc[l];
    }
    for j in n8..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// 1x4 register tile: one A row segment against four Bᵀ rows at once —
/// each A load is reused 4x, quadrupling arithmetic intensity.
#[inline(always)]
fn dot4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    let n = a.len();
    let n8 = n / 8 * 8;
    let mut a0 = [0.0f32; 8];
    let mut a1 = [0.0f32; 8];
    let mut a2 = [0.0f32; 8];
    let mut a3 = [0.0f32; 8];
    let mut i = 0;
    while i < n8 {
        let x = &a[i..i + 8];
        let y0 = &b0[i..i + 8];
        let y1 = &b1[i..i + 8];
        let y2 = &b2[i..i + 8];
        let y3 = &b3[i..i + 8];
        for l in 0..8 {
            a0[l] += x[l] * y0[l];
            a1[l] += x[l] * y1[l];
            a2[l] += x[l] * y2[l];
            a3[l] += x[l] * y3[l];
        }
        i += 8;
    }
    let mut out = [0.0f32; 4];
    for l in 0..8 {
        out[0] += a0[l];
        out[1] += a1[l];
        out[2] += a2[l];
        out[3] += a3[l];
    }
    for j in n8..n {
        out[0] += a[j] * b0[j];
        out[1] += a[j] * b1[j];
        out[2] += a[j] * b2[j];
        out[3] += a[j] * b3[j];
    }
    out
}

/// Single-thread blocked kernel: `c` (rows r0..r1 of C, zeroed here)
/// accumulates `A[r0..r1] · Bᵀ` where A is (m x k) and B is (n x k).
fn bt_kernel_rows(a: &[f32], bt: &[f32], c: &mut [f32], r0: usize, r1: usize, k: usize, n: usize) {
    for v in c.iter_mut() {
        *v = 0.0;
    }
    let mut kb = 0;
    while kb < k {
        let kend = (kb + KC).min(k);
        let mut jb = 0;
        while jb < n {
            let jend = (jb + JB).min(n);
            for i in r0..r1 {
                let arow = &a[i * k + kb..i * k + kend];
                let crow = &mut c[(i - r0) * n..(i - r0) * n + n];
                let mut j = jb;
                while j + 4 <= jend {
                    let s = dot4(
                        arow,
                        &bt[j * k + kb..j * k + kend],
                        &bt[(j + 1) * k + kb..(j + 1) * k + kend],
                        &bt[(j + 2) * k + kb..(j + 2) * k + kend],
                        &bt[(j + 3) * k + kb..(j + 3) * k + kend],
                    );
                    crow[j] += s[0];
                    crow[j + 1] += s[1];
                    crow[j + 2] += s[2];
                    crow[j + 3] += s[3];
                    j += 4;
                }
                while j < jend {
                    crow[j] += dot(arow, &bt[j * k + kb..j * k + kend]);
                    j += 1;
                }
            }
            jb = jend;
        }
        kb = kend;
    }
}

/// C (m x n) = A (m x k) @ B (n x k)ᵀ, parallel over row blocks of C.
pub fn matmul_bt_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), n * k, "B shape");
    assert_eq!(c.len(), m * n, "C shape");
    if m == 0 || n == 0 {
        return;
    }
    if m * k.max(1) * n < PAR_MIN_MACS {
        bt_kernel_rows(a, b, c, 0, m, k, n);
        return;
    }
    let rows_per = pool::rows_per_task(m);
    pool::parallel_chunks_mut(c, rows_per * n, |ci, chunk| {
        let r0 = ci * rows_per;
        let r1 = r0 + chunk.len() / n;
        bt_kernel_rows(a, b, chunk, r0, r1, k, n);
    });
}

/// Blocked (tile-transposed) out-of-place transpose: (rows x cols) ->
/// (cols x rows). Parallel over output row blocks for large operands.
pub fn transpose_into(a: &[f32], out: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(a.len(), rows * cols);
    assert_eq!(out.len(), rows * cols);
    const TB: usize = 32;
    let tile = |out_chunk: &mut [f32], j0: usize, j1: usize| {
        // out rows j0..j1 (original columns), blocked over the i axis.
        let mut ib = 0;
        while ib < rows {
            let iend = (ib + TB).min(rows);
            for j in j0..j1 {
                let orow = &mut out_chunk[(j - j0) * rows..(j - j0) * rows + rows];
                for i in ib..iend {
                    orow[i] = a[i * cols + j];
                }
            }
            ib = iend;
        }
    };
    if rows * cols < PAR_MIN_MACS {
        tile(out, 0, cols);
        return;
    }
    let jper = pool::rows_per_task(cols).max(TB);
    pool::parallel_chunks_mut(out, jper * rows, |ci, chunk| {
        let j0 = ci * jper;
        let j1 = j0 + chunk.len() / rows;
        tile(chunk, j0, j1);
    });
}

/// Seed reference kernels (naive loop nests, single-threaded). Kept as the
/// ground truth for the parallel/blocked property tests and for shapes so
/// small the blocked path is pure overhead.
pub mod scalar {
    /// C (m x n) = A (m x k) @ B (k x n), k-blocked axpy form.
    pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        matmul_into(a, b, &mut c, m, k, n);
        c
    }

    /// In-place form of [`matmul`] (the seed's allocation-free hot path).
    pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        assert_eq!(a.len(), m * k, "A shape");
        assert_eq!(b.len(), k * n, "B shape");
        assert_eq!(c.len(), m * n, "C shape");
        c.fill(0.0);
        const KB: usize = 64;
        for kb in (0..k).step_by(KB) {
            let kend = (kb + KB).min(k);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let av = arow[kk];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        }
    }

    /// C = A @ Bᵀ where A is (m x k), B is (n x k).
    pub fn matmul_bt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), n * k);
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &b[j * k..(j + 1) * k];
                let mut s = 0.0f32;
                for (x, y) in arow.iter().zip(brow) {
                    s += x * y;
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    /// C = Aᵀ @ B where A is (k x m), B is (k x n) -> (m x n).
    pub fn matmul_at(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
        assert_eq!(a.len(), k * m);
        assert_eq!(b.len(), k * n);
        let mut c = vec![0.0f32; m * n];
        for kk in 0..k {
            let arow = &a[kk * m..(kk + 1) * m];
            let brow = &b[kk * n..(kk + 1) * n];
            for i in 0..m {
                let av = arow[i];
                if av == 0.0 {
                    continue;
                }
                let crow = &mut c[i * n..(i + 1) * n];
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
        c
    }

    /// Column-strided softmax (the seed's cache-hostile traversal) — the
    /// numeric reference for the tiled `ops::softmax_cols`.
    pub fn softmax_cols(x: &mut [f32], rows: usize, cols: usize) {
        for j in 0..cols {
            let mut mx = f32::NEG_INFINITY;
            for i in 0..rows {
                mx = mx.max(x[i * cols + j]);
            }
            let mut z = 0.0f32;
            for i in 0..rows {
                let v = (x[i * cols + j] - mx).exp();
                x[i * cols + j] = v;
                z += v;
            }
            let inv = 1.0 / z.max(1e-20);
            for i in 0..rows {
                x[i * cols + j] *= inv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= tol * (1.0 + y.abs()),
                "elem {i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn bt_matches_scalar_ragged_shapes() {
        let mut rng = Pcg64::new(7);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 256, 64), (70, 65, 130)] {
            let a = rng.normal_vec(m * k);
            let b = rng.normal_vec(n * k);
            let mut c = vec![0.0f32; m * n];
            matmul_bt_into(&a, &b, &mut c, m, k, n);
            close(&c, &scalar::matmul_bt(&a, &b, m, k, n), 1e-4);
        }
    }

    #[test]
    fn bt_parallel_path_matches_scalar() {
        let mut rng = Pcg64::new(8);
        let (m, k, n) = (96, 300, 50); // above PAR_MIN_MACS
        let a = rng.normal_vec(m * k);
        let b = rng.normal_vec(n * k);
        let mut c = vec![0.0f32; m * n];
        matmul_bt_into(&a, &b, &mut c, m, k, n);
        close(&c, &scalar::matmul_bt(&a, &b, m, k, n), 1e-4);
    }

    #[test]
    fn transpose_into_blocked_matches_naive() {
        let mut rng = Pcg64::new(9);
        for (r, c) in [(1, 7), (33, 65), (128, 300)] {
            let a = rng.normal_vec(r * c);
            let mut t = vec![0.0f32; r * c];
            transpose_into(&a, &mut t, r, c);
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(t[j * r + i], a[i * c + j]);
                }
            }
        }
    }

    #[test]
    fn dot_handles_remainders() {
        for len in [0usize, 1, 7, 8, 9, 31] {
            let a: Vec<f32> = (0..len).map(|i| i as f32).collect();
            let b = vec![2.0f32; len];
            let expect: f32 = (0..len).map(|i| 2.0 * i as f32).sum();
            assert_eq!(dot(&a, &b), expect);
        }
    }
}
