//! Online per-lane anomaly detection: EWMA mean/variance z-score over
//! the per-lane step-latency stream, plus queue-depth, retry-rate and
//! (on cache-enabled lanes) plan-cache-miss channels.
//!
//! The detector is the *leading* health signal: cumulative histograms
//! (`Metrics::quantile_s`) move only after minutes of damage is already
//! in the books, while the per-lane `DecayedTail` reservoir and this
//! detector see each served step as it happens. A lane is flagged
//! `lane_degrading` after [`AnomalyPolicy::consecutive`] observations
//! breach the z-threshold on any channel, and the flag clears once every
//! channel calms down — both transitions are counted into [`Metrics`]
//! (`lane_degrading` / `lane_recovered`) and exposed programmatically as
//! [`AnomalyFlags`], which the future cross-lane formation controller
//! and the distributed tier's health checks consume. **Do not build new
//! control loops on cumulative histograms** — consume `AnomalyFlags` or
//! `DecayedTail`, which decay; see `coordinator::metrics`.
//!
//! Everything is observation-driven: `observe` takes explicit values,
//! never reads a clock, so tests drive the detector with synthetic
//! streams (e.g. replaying a `FaultPlan`) fully deterministically —
//! the same offset discipline as `scheduler::DecayedTail`.
//!
//! EWMA updates are *robust*: once armed (past warmup), samples that
//! breach the threshold are **not** folded into mean/variance, so a
//! degrading lane cannot drag its own baseline up and mask itself.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::sync::Mutex;

use crate::coordinator::metrics::Metrics;
use crate::util::lock_unpoisoned;

/// Signal channels tracked independently per lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Channel {
    /// Seconds per cohort/engine step (the `DecayedTail` stream).
    StepLatency = 0,
    /// Jobs waiting when a formation round closed / a worker dequeued.
    QueueDepth = 1,
    /// 0/1 stream: was this completion a retry/respawn event?
    RetryRate = 2,
    /// 0/1 stream (PR 8): did this refresh boundary miss the plan cache?
    /// Only fed on cache-enabled lanes; a collapsing hit rate raises
    /// `lane_degrading` before the lost selections show up in step
    /// latency.
    CacheMiss = 3,
}

pub const CHANNEL_COUNT: usize = 4;

impl Channel {
    pub fn as_str(&self) -> &'static str {
        match self {
            Channel::StepLatency => "step-latency",
            Channel::QueueDepth => "queue-depth",
            Channel::RetryRate => "retry-rate",
            Channel::CacheMiss => "cache-miss",
        }
    }
}

/// Detector tuning. Defaults are deliberately conservative: a lane must
/// breach 4 sigma on three consecutive observations before flagging.
#[derive(Clone, Copy, Debug)]
pub struct AnomalyPolicy {
    /// EWMA weight for mean/variance updates.
    pub alpha: f64,
    /// One-sided z-score breach threshold (high side only: slow steps,
    /// deep queues, and retries are anomalies; fast/empty never is).
    pub z_threshold: f64,
    /// Observations per channel before the detector arms.
    pub warmup: u32,
    /// Consecutive breaches to raise the flag; consecutive normal
    /// observations (on some channel, with all channels calm) to clear.
    pub consecutive: u32,
    /// Variance floor as a fraction of the mean, so a perfectly steady
    /// baseline (variance zero) still yields finite z-scores.
    pub sigma_floor_frac: f64,
}

impl Default for AnomalyPolicy {
    fn default() -> Self {
        AnomalyPolicy {
            alpha: 0.1,
            z_threshold: 4.0,
            warmup: 16,
            consecutive: 3,
            sigma_floor_frac: 0.1,
        }
    }
}

#[derive(Clone, Copy, Default)]
struct ChannelState {
    mean: f64,
    var: f64,
    count: u64,
    breaches: u32,
    normals: u32,
}

#[derive(Default)]
struct LaneState {
    channels: [ChannelState; CHANNEL_COUNT],
    degrading: bool,
}

/// Snapshot of currently-flagged lanes — the programmatic trigger for
/// the cross-lane controller and distributed health checks.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct AnomalyFlags {
    /// Sorted keys of lanes currently flagged as degrading.
    pub lanes: Vec<String>,
}

impl AnomalyFlags {
    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }

    pub fn contains(&self, lane: &str) -> bool {
        self.lanes.iter().any(|l| l == lane)
    }
}

struct Inner {
    policy: AnomalyPolicy,
    lanes: Mutex<BTreeMap<String, LaneState>>,
}

/// Shared online detector; cheap to clone (one `Arc`), one mutexed map
/// update per observation — observations happen per cohort step / per
/// request completion, never per token, so this is far off the GEMM
/// hot path.
#[derive(Clone)]
pub struct AnomalyDetector {
    inner: Arc<Inner>,
}

impl Default for AnomalyDetector {
    fn default() -> Self {
        AnomalyDetector::new(AnomalyPolicy::default())
    }
}

impl AnomalyDetector {
    pub fn new(policy: AnomalyPolicy) -> Self {
        AnomalyDetector {
            inner: Arc::new(Inner {
                policy,
                lanes: Mutex::new(BTreeMap::new()),
            }),
        }
    }

    pub fn policy(&self) -> AnomalyPolicy {
        self.inner.policy
    }

    /// Feed one observation. Returns `Some(true)` when this observation
    /// raised the lane's degrading flag, `Some(false)` when it cleared
    /// it, `None` on no transition.
    pub fn observe(&self, lane: &str, channel: Channel, value: f64) -> Option<bool> {
        let p = self.inner.policy;
        let mut lanes = lock_unpoisoned(&self.inner.lanes);
        let st = match lanes.get_mut(lane) {
            Some(st) => st,
            // Allocates the lane key once per lane lifetime, not per call.
            None => lanes.entry(lane.to_string()).or_default(),
        };
        let cs = &mut st.channels[channel as usize];
        cs.count += 1;
        if cs.count == 1 {
            cs.mean = value;
            cs.var = 0.0;
            return None;
        }
        let diff = value - cs.mean;
        let sigma = cs.var.sqrt().max(p.sigma_floor_frac * cs.mean.abs()).max(1e-12);
        let armed = cs.count > p.warmup as u64;
        if armed && diff / sigma > p.z_threshold {
            cs.breaches += 1;
            cs.normals = 0;
            // Robust EWMA: anomalous samples are not learned.
        } else {
            cs.breaches = 0;
            cs.normals = cs.normals.saturating_add(1);
            let incr = p.alpha * diff;
            cs.mean += incr;
            cs.var = (1.0 - p.alpha) * (cs.var + diff * incr);
        }
        let was = st.degrading;
        let breached = st.channels.iter().any(|c| c.breaches >= p.consecutive);
        if !was && breached {
            st.degrading = true;
            return Some(true);
        }
        let calm = st.channels.iter().all(|c| c.breaches == 0);
        let settled = st.channels.iter().any(|c| c.normals >= p.consecutive);
        if was && calm && settled {
            st.degrading = false;
            return Some(false);
        }
        None
    }

    /// [`AnomalyDetector::observe`], counting flag transitions into the
    /// metrics registry (`lane_degrading` / `lane_recovered`).
    pub fn observe_with_metrics(
        &self,
        lane: &str,
        channel: Channel,
        value: f64,
        metrics: &Metrics,
    ) {
        match self.observe(lane, channel, value) {
            Some(true) => metrics.inc("lane_degrading"),
            Some(false) => metrics.inc("lane_recovered"),
            None => {}
        }
    }

    pub fn is_degrading(&self, lane: &str) -> bool {
        lock_unpoisoned(&self.inner.lanes).get(lane).is_some_and(|st| st.degrading)
    }

    /// Snapshot of currently-flagged lanes (sorted by lane key).
    pub fn flags(&self) -> AnomalyFlags {
        let lanes = lock_unpoisoned(&self.inner.lanes);
        AnomalyFlags {
            lanes: lanes
                .iter()
                .filter(|(_, st)| st.degrading)
                .map(|(k, _)| k.clone())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_policy() -> AnomalyPolicy {
        AnomalyPolicy {
            warmup: 8,
            consecutive: 3,
            ..AnomalyPolicy::default()
        }
    }

    /// Feed `n` baseline observations with a deterministic ±5% jitter.
    fn warm(d: &AnomalyDetector, lane: &str, ch: Channel, base: f64, n: usize) {
        for i in 0..n {
            let jitter = 1.0 + 0.05 * if i % 2 == 0 { 1.0 } else { -1.0 };
            assert_eq!(d.observe(lane, ch, base * jitter), None, "baseline must not flag");
        }
    }

    #[test]
    fn spike_flags_after_consecutive_breaches() {
        let d = AnomalyDetector::new(fast_policy());
        warm(&d, "lane-a", Channel::StepLatency, 0.001, 32);
        // 20x latency: two breaches are not enough, the third flips it.
        assert_eq!(d.observe("lane-a", Channel::StepLatency, 0.02), None);
        assert_eq!(d.observe("lane-a", Channel::StepLatency, 0.02), None);
        assert_eq!(d.observe("lane-a", Channel::StepLatency, 0.02), Some(true));
        assert!(d.is_degrading("lane-a"));
        assert_eq!(d.flags().lanes, vec!["lane-a".to_string()]);
    }

    #[test]
    fn spike_during_warmup_does_not_flag() {
        let d = AnomalyDetector::new(fast_policy());
        for _ in 0..4 {
            assert_eq!(d.observe("lane-a", Channel::StepLatency, 0.001), None);
        }
        assert_eq!(d.observe("lane-a", Channel::StepLatency, 0.05), None);
        assert!(!d.is_degrading("lane-a"));
    }

    #[test]
    fn steady_baseline_with_zero_variance_still_detects() {
        let d = AnomalyDetector::new(fast_policy());
        for _ in 0..32 {
            d.observe("lane-a", Channel::QueueDepth, 4.0);
        }
        for _ in 0..2 {
            assert_eq!(d.observe("lane-a", Channel::QueueDepth, 64.0), None);
        }
        assert_eq!(d.observe("lane-a", Channel::QueueDepth, 64.0), Some(true));
    }

    #[test]
    fn jitter_does_not_flag() {
        let d = AnomalyDetector::new(fast_policy());
        warm(&d, "lane-a", Channel::StepLatency, 0.001, 200);
        assert!(!d.is_degrading("lane-a"));
        assert!(d.flags().is_empty());
    }

    #[test]
    fn flag_is_per_lane_and_per_channel() {
        let d = AnomalyDetector::new(fast_policy());
        warm(&d, "lane-a", Channel::StepLatency, 0.001, 32);
        warm(&d, "lane-b", Channel::StepLatency, 0.001, 32);
        for _ in 0..5 {
            d.observe("lane-a", Channel::StepLatency, 0.02);
        }
        assert!(d.is_degrading("lane-a"));
        assert!(!d.is_degrading("lane-b"), "healthy lane must stay unflagged");
        let flags = d.flags();
        assert!(flags.contains("lane-a") && !flags.contains("lane-b"));
    }

    #[test]
    fn recovery_clears_flag() {
        let d = AnomalyDetector::new(fast_policy());
        warm(&d, "lane-a", Channel::StepLatency, 0.001, 32);
        for _ in 0..5 {
            d.observe("lane-a", Channel::StepLatency, 0.02);
        }
        assert!(d.is_degrading("lane-a"));
        let mut cleared = None;
        for _ in 0..8 {
            if let Some(false) = d.observe("lane-a", Channel::StepLatency, 0.001) {
                cleared = Some(false);
                break;
            }
        }
        assert_eq!(cleared, Some(false), "flag must clear after calm observations");
        assert!(!d.is_degrading("lane-a"));
        assert!(d.flags().is_empty());
    }

    #[test]
    fn baseline_is_not_dragged_by_anomalies() {
        // Sustained 20x degradation must keep breaching: robust EWMA
        // refuses to learn the anomalous level as the new normal.
        let d = AnomalyDetector::new(fast_policy());
        warm(&d, "lane-a", Channel::StepLatency, 0.001, 32);
        for _ in 0..5 {
            d.observe("lane-a", Channel::StepLatency, 0.02);
        }
        assert!(d.is_degrading("lane-a"));
        for _ in 0..100 {
            d.observe("lane-a", Channel::StepLatency, 0.02);
        }
        assert!(d.is_degrading("lane-a"), "sustained anomaly must stay flagged");
    }

    #[test]
    fn cache_miss_stream_flags_on_collapsing_hit_rate() {
        // PR 8: the scheduler feeds a 0/1 miss indicator per refresh
        // boundary. A steady all-hit lane that starts missing every
        // probe must flag on the miss channel alone.
        let d = AnomalyDetector::new(fast_policy());
        for _ in 0..32 {
            assert_eq!(d.observe("lane-a", Channel::CacheMiss, 0.0), None);
        }
        assert_eq!(d.observe("lane-a", Channel::CacheMiss, 1.0), None);
        assert_eq!(d.observe("lane-a", Channel::CacheMiss, 1.0), None);
        assert_eq!(d.observe("lane-a", Channel::CacheMiss, 1.0), Some(true));
        assert!(d.is_degrading("lane-a"));
    }

    #[test]
    fn transitions_count_into_metrics() {
        let m = Metrics::new();
        let d = AnomalyDetector::new(fast_policy());
        warm(&d, "lane-a", Channel::StepLatency, 0.001, 32);
        for _ in 0..5 {
            d.observe_with_metrics("lane-a", Channel::StepLatency, 0.02, &m);
        }
        assert_eq!(m.counter("lane_degrading"), 1, "one transition, one count");
        for _ in 0..8 {
            d.observe_with_metrics("lane-a", Channel::StepLatency, 0.001, &m);
        }
        assert_eq!(m.counter("lane_recovered"), 1);
    }
}
