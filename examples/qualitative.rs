//! Qualitative grid (Fig. 1 / Fig. 5 stand-in): PGM latent previews for a
//! few prompts across variants + the per-image DINO-proxy scores.
//!
//! ```bash
//! cargo run --release --example qualitative -- --out-dir /tmp/toma_quals
//! ```

use std::sync::Arc;

use toma::util::error::Result;
use toma::coordinator::{Engine, EngineConfig, GenRequest};
use toma::quality::{dino_proxy, write_pgm_preview, FeatureExtractor};
use toma::runtime::Runtime;
use toma::util::argparse::Args;
use toma::workload::PromptSet;

fn main() -> Result<()> {
    let args = Args::from_env();
    let model = args.get_str("model", "uvit_xs");
    let steps = args.get_usize("steps", 12);
    let out_dir = args.get_str("out-dir", "/tmp/toma_quals");
    std::fs::create_dir_all(&out_dir)?;

    let runtime = Arc::new(Runtime::with_default_dir()?);
    let info = runtime.manifest.model(&model)?.clone();
    let prompts = PromptSet::gemrec();
    let chosen: Vec<&str> = (0..4).map(|i| prompts.get(i * 5)).collect();

    let variants: Vec<(&str, Option<f64>)> = vec![
        ("baseline", None),
        ("toma", Some(0.25)),
        ("toma", Some(0.5)),
        ("toma", Some(0.75)),
    ];

    let mut baselines: Vec<Vec<f32>> = vec![];
    let fx = FeatureExtractor::new(info.latent_len() / info.batch, 32, 5);

    println!("prompt grid -> {out_dir}/<prompt>_<variant>.pgm");
    for (variant, ratio) in &variants {
        let mut cfg = EngineConfig::new(&model, variant, *ratio);
        cfg.steps = steps;
        let engine = Engine::new(runtime.clone(), cfg)?;
        for (pi, prompt) in chosen.iter().enumerate() {
            let r = engine.generate(&GenRequest::new(prompt, pi as u64))?;
            let tag = ratio
                .map(|x| format!("{variant}_r{:02}", (x * 100.0) as u32))
                .unwrap_or_else(|| variant.to_string());
            let path = format!("{out_dir}/p{pi}_{tag}.pgm");
            write_pgm_preview(&r.latent, info.channels, info.latent_hw, &path)?;
            if *variant == "baseline" {
                baselines.push(r.latent);
                println!("  p{pi} {tag}: reference");
            } else {
                let d = dino_proxy(&fx, &baselines[pi], &r.latent);
                println!("  p{pi} {tag}: DINOp={d:.4}");
            }
        }
    }
    Ok(())
}
