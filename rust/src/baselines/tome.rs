//! ToMeSD bipartite soft matching (Bolya & Hoffman 2023) and the ToFu
//! merge/prune blend (Kim et al. 2023).
//!
//! Pipeline (per plan):
//!   1. destinations = one token per 2x2 spatial window; sources = rest;
//!   2. score every source against every destination (cosine);
//!   3. **sort** sources by best-match similarity (the GPU-inefficient
//!      step ToMA eliminates);
//!   4. merge: **gather** the top-r sources, **scatter-add** them into
//!      their destinations, divide by counts;
//!   5. unmerge: copy each destination embedding back to the source
//!      positions merged into it.
//!
//! ToFu reuses the matching but either merges (averaging) or prunes
//! (destinations unchanged) depending on the block's linearity regime.

use crate::tensor::ops::{argsort_desc, gather_rows, l2_normalize_rows, matmul_bt, scatter_add_rows};
use crate::tensor::pool;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TomeMode {
    Merge,
    Prune,
}

/// A bipartite merge plan for one batch element on an (h x w) token grid.
#[derive(Clone, Debug)]
pub struct TomePlan {
    pub dst_idx: Vec<usize>,   // global ids of destination tokens
    pub src_idx: Vec<usize>,   // global ids of source tokens
    pub order: Vec<usize>,     // source slots sorted by match quality (desc)
    pub node_idx: Vec<usize>,  // best destination slot per source slot
    pub k: usize,              // number of sources merged away
    pub mode: TomeMode,
    pub n: usize,
}

impl TomePlan {
    /// Build the matching from features x (n x d) on an (h x w) grid.
    /// `ratio` is the fraction of the total sequence merged away, capped by
    /// the source count (3/4 at 2x2 stride).
    pub fn build(x: &[f32], h: usize, w: usize, d: usize, ratio: f32, mode: TomeMode) -> TomePlan {
        let n = h * w;
        assert_eq!(x.len(), n * d);
        let mut dst_idx = vec![];
        let mut src_idx = vec![];
        for r in 0..h {
            for c in 0..w {
                if r % 2 == 0 && c % 2 == 0 {
                    dst_idx.push(r * w + c);
                } else {
                    src_idx.push(r * w + c);
                }
            }
        }
        let n_src = src_idx.len();
        let k = ((ratio * n as f32).round() as usize).min(n_src);

        let mut xn = x.to_vec();
        l2_normalize_rows(&mut xn, n, d);
        let hs = gather_rows(&xn, d, &src_idx);
        let hd = gather_rows(&xn, d, &dst_idx);
        let scores = matmul_bt(&hs, &hd, n_src, d, dst_idx.len());

        // Best destination per source: independent row scans, fanned out
        // over the worker pool (same substrate as the ToMA side, so the
        // Table 6 comparison stays algorithmic). Small score matrices stay
        // serial — pool dispatch would dominate the scan.
        let mut node_max = vec![f32::NEG_INFINITY; n_src];
        let mut node_idx = vec![0usize; n_src];
        let n_dst = dst_idx.len();
        let scan = |s: usize, best: &mut f32, arg: &mut usize| {
            let row = &scores[s * n_dst..(s + 1) * n_dst];
            for (t, &v) in row.iter().enumerate() {
                if v > *best {
                    *best = v;
                    *arg = t;
                }
            }
        };
        if n_src * n_dst < pool::PAR_MIN_ELEMS {
            for s in 0..n_src {
                scan(s, &mut node_max[s], &mut node_idx[s]);
            }
        } else {
            let per = pool::rows_per_task(n_src);
            pool::parallel_chunks2_mut(&mut node_max, &mut node_idx, per, |ci, cm, cidx| {
                for off in 0..cm.len() {
                    scan(ci * per + off, &mut cm[off], &mut cidx[off]);
                }
            });
        }
        // The characteristic full sort over sources.
        let order = argsort_desc(&node_max);

        TomePlan {
            dst_idx,
            src_idx,
            order,
            node_idx,
            k,
            mode,
            n,
        }
    }

    pub fn merged_len(&self) -> usize {
        self.n - self.k
    }

    /// Merge: (n x d) -> (merged_len x d), kept sources first then dests.
    pub fn merge(&self, x: &[f32], d: usize) -> Vec<f32> {
        assert_eq!(x.len(), self.n * d);
        let xs = gather_rows(x, d, &self.src_idx);
        let mut xd = gather_rows(x, d, &self.dst_idx);
        let kept: Vec<usize> = self.order[self.k..]
            .iter()
            .map(|&s| self.src_idx[s])
            .collect();
        let x_kept = gather_rows(x, d, &kept);

        if self.mode == TomeMode::Merge && self.k > 0 {
            let merged_slots = &self.order[..self.k];
            let merged_rows: Vec<f32> = merged_slots
                .iter()
                .flat_map(|&s| xs[s * d..(s + 1) * d].to_vec())
                .collect();
            let targets: Vec<usize> = merged_slots.iter().map(|&s| self.node_idx[s]).collect();
            // Scatter-add + count normalization (destination keeps weight 1).
            scatter_add_rows(&merged_rows, d, &targets, &mut xd);
            let mut counts = vec![1.0f32; self.dst_idx.len()];
            for &t in &targets {
                counts[t] += 1.0;
            }
            for (t, row) in xd.chunks_mut(d).enumerate() {
                let inv = 1.0 / counts[t];
                for v in row {
                    *v *= inv;
                }
            }
        }
        let mut out = x_kept;
        out.extend_from_slice(&xd);
        out
    }

    /// Unmerge: (merged_len x d) -> (n x d).
    pub fn unmerge(&self, y: &[f32], d: usize) -> Vec<f32> {
        assert_eq!(y.len(), self.merged_len() * d);
        let n_keep = self.src_idx.len() - self.k;
        let y_kept = &y[..n_keep * d];
        let y_dst = &y[n_keep * d..];
        let mut out = vec![0.0f32; self.n * d];
        for (i, &s) in self.order[self.k..].iter().enumerate() {
            let g = self.src_idx[s];
            out[g * d..(g + 1) * d].copy_from_slice(&y_kept[i * d..(i + 1) * d]);
        }
        for (i, &s) in self.order[..self.k].iter().enumerate() {
            let _ = i;
            let g = self.src_idx[s];
            let t = self.node_idx[s];
            out[g * d..(g + 1) * d].copy_from_slice(&y_dst[t * d..(t + 1) * d]);
        }
        for (t, &g) in self.dst_idx.iter().enumerate() {
            out[g * d..(g + 1) * d].copy_from_slice(&y_dst[t * d..(t + 1) * d]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Pcg64};

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        Pcg64::new(seed).normal_vec(n)
    }

    #[test]
    fn partition_covers_grid() {
        let x = randn(64 * 4, 0);
        let p = TomePlan::build(&x, 8, 8, 4, 0.5, TomeMode::Merge);
        let mut all: Vec<usize> = p.dst_idx.iter().chain(&p.src_idx).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..64).collect::<Vec<_>>());
        assert_eq!(p.dst_idx.len(), 16);
    }

    #[test]
    fn k_capped_by_sources() {
        let x = randn(64 * 4, 1);
        let p = TomePlan::build(&x, 8, 8, 4, 0.95, TomeMode::Merge);
        assert_eq!(p.k, 48);
        assert_eq!(p.merged_len(), 16);
    }

    #[test]
    fn merge_unmerge_shapes() {
        let x = randn(64 * 4, 2);
        let p = TomePlan::build(&x, 8, 8, 4, 0.5, TomeMode::Merge);
        let y = p.merge(&x, 4);
        assert_eq!(y.len(), p.merged_len() * 4);
        let back = p.unmerge(&y, 4);
        assert_eq!(back.len(), 64 * 4);
    }

    #[test]
    fn kept_tokens_roundtrip_exactly() {
        let x = randn(64 * 4, 3);
        let p = TomePlan::build(&x, 8, 8, 4, 0.25, TomeMode::Merge);
        let back = p.unmerge(&p.merge(&x, 4), 4);
        for &s in &p.order[p.k..] {
            let g = p.src_idx[s];
            assert_eq!(&back[g * 4..(g + 1) * 4], &x[g * 4..(g + 1) * 4]);
        }
    }

    #[test]
    fn merged_sources_get_destination_value() {
        let x = randn(64 * 4, 4);
        let p = TomePlan::build(&x, 8, 8, 4, 0.5, TomeMode::Merge);
        let y = p.merge(&x, 4);
        let back = p.unmerge(&y, 4);
        for &s in &p.order[..p.k] {
            let g_src = p.src_idx[s];
            let g_dst = p.dst_idx[p.node_idx[s]];
            assert_eq!(&back[g_src * 4..(g_src + 1) * 4],
                       &back[g_dst * 4..(g_dst + 1) * 4]);
        }
    }

    #[test]
    fn prune_keeps_destinations_unchanged() {
        let x = randn(64 * 4, 5);
        let p = TomePlan::build(&x, 8, 8, 4, 0.5, TomeMode::Prune);
        let y = p.merge(&x, 4);
        let n_keep = p.src_idx.len() - p.k;
        for (t, &g) in p.dst_idx.iter().enumerate() {
            assert_eq!(&y[(n_keep + t) * 4..(n_keep + t + 1) * 4],
                       &x[g * 4..(g + 1) * 4]);
        }
    }

    #[test]
    fn order_ranks_by_similarity() {
        let x = randn(64 * 8, 6);
        let p = TomePlan::build(&x, 8, 8, 8, 0.5, TomeMode::Merge);
        // Recompute node_max and verify the order is non-increasing.
        let mut xn = x.clone();
        l2_normalize_rows(&mut xn, 64, 8);
        let hs = gather_rows(&xn, 8, &p.src_idx);
        let hd = gather_rows(&xn, 8, &p.dst_idx);
        let sc = matmul_bt(&hs, &hd, p.src_idx.len(), 8, p.dst_idx.len());
        let best: Vec<f32> = (0..p.src_idx.len())
            .map(|s| {
                (0..p.dst_idx.len())
                    .map(|t| sc[s * p.dst_idx.len() + t])
                    .fold(f32::NEG_INFINITY, f32::max)
            })
            .collect();
        let ranked: Vec<f32> = p.order.iter().map(|&s| best[s]).collect();
        assert!(ranked.windows(2).all(|w| w[0] >= w[1] - 1e-5));
    }

    #[test]
    fn prop_unmerge_fills_everything() {
        prop::check("tome fills", 12, |g| {
            let hw = *g.pick(&[4usize, 8]);
            let d = g.usize_in(2, 6);
            let ratio = *g.pick(&[0.25f32, 0.5, 0.75]);
            let x: Vec<f32> = g
                .normal_vec(hw * hw * d)
                .iter()
                .map(|v| v + 3.0)
                .collect();
            let p = TomePlan::build(&x, hw, hw, d, ratio, TomeMode::Merge);
            let back = p.unmerge(&p.merge(&x, d), d);
            // Shifted inputs are strictly positive on average per row.
            for r in 0..hw * hw {
                let s: f32 = back[r * d..(r + 1) * d].iter().map(|v| v.abs()).sum();
                prop::assert_prop(s > 0.0, "position filled");
            }
        });
    }
}
