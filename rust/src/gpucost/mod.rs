//! Analytic GPU cost model (see DESIGN.md §substitutions).
//!
//! The paper's latency tables were measured on NVIDIA RTX6000 / V100 /
//! RTX8000 hardware we do not have. This module rebuilds them from first
//! principles: every step of every variant is described as a sequence of
//! [`ops::Op`]s (GEMMs, fused attention, softmax, gathers, scatters, sorts,
//! relayout copies, kernel launches), and a per-device roofline converts
//! the sequence to seconds.
//!
//! Calibration policy: each device profile has a single global `speed`
//! factor anchored on the paper's *baseline* rows (SDXL 6.1 s on RTX6000,
//! etc.). Everything else — the relative cost of ToMA vs ToMe vs TLB, the
//! ratio sweeps, the tile/stripe gap — is *predicted* by the model, never
//! fitted. The acceptance criterion is shape fidelity (who wins, by what
//! factor, where crossovers fall), per DESIGN.md.

pub mod calibrate;
pub mod device;
pub mod flops;
pub mod memory;
pub mod ops;
pub mod roofline;
pub mod workloads;

pub use calibrate::calibrated_sec_per_img;
pub use device::{Gpu, GpuModel};
pub use ops::Op;
pub use roofline::estimate_time;
pub use workloads::{PaperModel, StepWorkload, Variant};
