//! k-means (Lloyd's algorithm, k-means++ seeding) for the latent-locality
//! analysis of Fig. 3 / Fig. 9: clustering hidden states and measuring how
//! spatially coherent the clusters are across blocks and denoising steps.
//!
//! Since PR 5 the Lloyd assignment step — the O(n·k·d) hot loop, formerly
//! a naive per-pair `dist2` scan — is lowered onto the tensor substrate:
//! nearest centroids come from one `X · Cᵀ` GEMM per round on the
//! microkernel seam (`argmin_c ||x−c||² = argmin_c (||c||² − 2 x·c)`),
//! with the chosen centroid's exact squared distance feeding the inertia
//! as before. Seeding keeps the per-pair scan (it is O(n·d) per round and
//! feeds a weighted draw, not an argmin).

use super::{kernel, ops};
use crate::util::Pcg64;

pub struct KMeans {
    pub centroids: Vec<f32>, // (k, d)
    pub assignments: Vec<usize>,
    pub k: usize,
    pub d: usize,
    pub inertia: f32,
}

fn dist2(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// One assignment pass: nearest centroid per point via the GEMM-scored
/// rule `argmin_c (||c||² − 2 x·c)`, writing `assignments` and returning
/// the exact inertia (sum of true squared distances to the chosen
/// centroids).
///
/// Accuracy caveat (the standard GEMM k-means tradeoff, same as
/// scikit-learn's `euclidean_distances`): dropping the common `||x||²`
/// term is exact in real arithmetic but the score's rounding error is
/// relative to `||x||·||c||`, not to the distance gap — so for points
/// with a large common offset (uncentered features) the winner can flip
/// between *nearly* equidistant centroids, not just exact ties. The
/// latent-locality features this clusters are roughly centered, and the
/// inertia is always recomputed from the true distance of the pick.
fn assign(
    x: &[f32],
    n: usize,
    d: usize,
    centroids: &[f32],
    k: usize,
    assignments: &mut [usize],
) -> f32 {
    let xc = ops::matmul_bt(x, centroids, n, d, k);
    let cnorm: Vec<f32> = (0..k)
        .map(|c| {
            let row = &centroids[c * d..(c + 1) * d];
            kernel::dot_e(row, row)
        })
        .collect();
    let mut inertia = 0.0f32;
    for i in 0..n {
        let scores = &xc[i * k..(i + 1) * k];
        let mut best = 0;
        let mut bs = f32::INFINITY;
        for c in 0..k {
            let s = cnorm[c] - 2.0 * scores[c];
            if s < bs {
                bs = s;
                best = c;
            }
        }
        assignments[i] = best;
        inertia += dist2(&x[i * d..(i + 1) * d], &centroids[best * d..(best + 1) * d]);
    }
    inertia
}

/// Cluster `n` points of dim `d` into `k` clusters.
pub fn kmeans(x: &[f32], n: usize, d: usize, k: usize, iters: usize, rng: &mut Pcg64) -> KMeans {
    assert_eq!(x.len(), n * d);
    assert!(k >= 1 && k <= n);

    // k-means++ seeding.
    let mut centroids = vec![0.0f32; k * d];
    let first = rng.below(n);
    centroids[..d].copy_from_slice(&x[first * d..(first + 1) * d]);
    let mut min_d2: Vec<f32> = (0..n)
        .map(|i| dist2(&x[i * d..(i + 1) * d], &centroids[..d]))
        .collect();
    for c in 1..k {
        let total: f32 = min_d2.iter().sum();
        let mut pick = n - 1;
        if total > 0.0 {
            let mut target = rng.next_f32() * total;
            for (i, w) in min_d2.iter().enumerate() {
                target -= w;
                if target <= 0.0 {
                    pick = i;
                    break;
                }
            }
        } else {
            pick = rng.below(n);
        }
        centroids[c * d..(c + 1) * d].copy_from_slice(&x[pick * d..(pick + 1) * d]);
        for i in 0..n {
            let dd = dist2(&x[i * d..(i + 1) * d], &centroids[c * d..(c + 1) * d]);
            if dd < min_d2[i] {
                min_d2[i] = dd;
            }
        }
    }

    let mut assignments = vec![0usize; n];
    let mut inertia = 0.0;
    for _ in 0..iters {
        // Assign: one X · Cᵀ GEMM on the kernel seam scores every
        // (point, centroid) pair; inertia stays the exact distance.
        inertia = assign(x, n, d, &centroids, k, &mut assignments);
        // Update.
        let mut sums = vec![0.0f32; k * d];
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let c = assignments[i];
            counts[c] += 1;
            for j in 0..d {
                sums[c * d + j] += x[i * d + j];
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for j in 0..d {
                    centroids[c * d + j] = sums[c * d + j] / counts[c] as f32;
                }
            }
        }
    }

    KMeans {
        centroids,
        assignments,
        k,
        d,
        inertia,
    }
}

/// Spatial-coherence score for cluster labels on an (h x w) token grid:
/// the fraction of 4-neighbour edges whose endpoints share a label.
/// Random labels with k clusters score ~1/k; a blocky segmentation (the
/// paper's Fig. 3 claim) scores much higher.
pub fn spatial_coherence(labels: &[usize], h: usize, w: usize) -> f64 {
    assert_eq!(labels.len(), h * w);
    let mut same = 0usize;
    let mut total = 0usize;
    for r in 0..h {
        for c in 0..w {
            if c + 1 < w {
                total += 1;
                if labels[r * w + c] == labels[r * w + c + 1] {
                    same += 1;
                }
            }
            if r + 1 < h {
                total += 1;
                if labels[r * w + c] == labels[(r + 1) * w + c] {
                    same += 1;
                }
            }
        }
    }
    same as f64 / total.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn separates_two_blobs() {
        let mut rng = Pcg64::new(0);
        let mut pts = vec![];
        for _ in 0..50 {
            pts.push(rng.normal() * 0.1 + 5.0);
            pts.push(rng.normal() * 0.1 + 5.0);
        }
        for _ in 0..50 {
            pts.push(rng.normal() * 0.1 - 5.0);
            pts.push(rng.normal() * 0.1 - 5.0);
        }
        let km = kmeans(&pts, 100, 2, 2, 10, &mut rng);
        let first = km.assignments[0];
        assert!(km.assignments[..50].iter().all(|&a| a == first));
        assert!(km.assignments[50..].iter().all(|&a| a != first));
    }

    #[test]
    fn inertia_decreases_with_k() {
        let mut rng = Pcg64::new(1);
        let pts: Vec<f32> = rng.normal_vec(200 * 3);
        let i2 = kmeans(&pts, 200, 3, 2, 15, &mut rng.fork(1)).inertia;
        let i8 = kmeans(&pts, 200, 3, 8, 15, &mut rng.fork(2)).inertia;
        assert!(i8 < i2);
    }

    #[test]
    fn coherence_of_blocky_vs_random() {
        // Left half label 0, right half label 1 -> high coherence.
        let mut blocky = vec![0usize; 64];
        for r in 0..8 {
            for c in 4..8 {
                blocky[r * 8 + c] = 1;
            }
        }
        let cb = spatial_coherence(&blocky, 8, 8);
        let mut rng = Pcg64::new(2);
        let random: Vec<usize> = (0..64).map(|_| rng.below(2)).collect();
        let cr = spatial_coherence(&random, 8, 8);
        assert!(cb > 0.9, "blocky {cb}");
        assert!(cb > cr, "blocky {cb} vs random {cr}");
    }

    #[test]
    fn gemm_assignment_matches_naive_dist2_scan() {
        // Equivalence with the seed's per-pair scan on (roughly
        // centered) data like the latent features this module clusters:
        // the GEMM-scored winner's *true* distance must match the naive
        // minimum to float tolerance — score rounding may flip the pick
        // only between near-equidistant centroids (see `assign`'s
        // accuracy caveat for the uncentered-data limits).
        let mut rng = Pcg64::new(9);
        for trial in 0..10usize {
            let n = 40 + trial;
            let d = 3 + trial % 5;
            let k = 2 + trial % 7;
            let x = rng.normal_vec(n * d);
            let c = rng.normal_vec(k * d);
            let mut got = vec![0usize; n];
            let inertia = assign(&x, n, d, &c, k, &mut got);
            let mut naive_inertia = 0.0f32;
            for i in 0..n {
                let p = &x[i * d..(i + 1) * d];
                let mut bd = f32::INFINITY;
                for cc in 0..k {
                    let dd = dist2(p, &c[cc * d..(cc + 1) * d]);
                    if dd < bd {
                        bd = dd;
                    }
                }
                naive_inertia += bd;
                let dd_got = dist2(p, &c[got[i] * d..(got[i] + 1) * d]);
                assert!(
                    (dd_got - bd).abs() <= 1e-4 * (1.0 + bd),
                    "point {i}: picked dist {dd_got} vs naive min {bd}"
                );
            }
            assert!((inertia - naive_inertia).abs() <= 1e-3 * (1.0 + naive_inertia));
        }
    }

    #[test]
    fn k_equals_n_zero_inertia() {
        let pts = vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0];
        let mut rng = Pcg64::new(3);
        let km = kmeans(&pts, 3, 2, 3, 5, &mut rng);
        assert!(km.inertia < 1e-9);
    }
}
