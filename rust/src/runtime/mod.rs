//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! serve path. Python never runs here — the manifest + HLO text + weight
//! npz files produced by `make artifacts` are the entire interface.
//!
//! The XLA-backed execution layer (`executor.rs`, `weights.rs`) is gated
//! behind the `pjrt` cargo feature. Without it (the default), pure-Rust
//! stubs with the identical API surface are compiled instead, so the whole
//! crate — engine, server, benches, examples — builds and tests on a bare
//! Rust toolchain; execution entry points then return a "built without
//! pjrt" error. Manifest parsing (`artifact.rs`) is pure Rust either way.

pub mod artifact;

#[cfg(feature = "pjrt")]
pub mod executor;
#[cfg(not(feature = "pjrt"))]
#[path = "executor_stub.rs"]
pub mod executor;

#[cfg(feature = "pjrt")]
pub mod weights;
#[cfg(not(feature = "pjrt"))]
#[path = "weights_stub.rs"]
pub mod weights;

pub use artifact::{ArtifactEntry, ArtifactKind, Dtype, Manifest, ModelInfo, TensorSpec};
pub use executor::{Executor, Runtime};
pub use weights::WeightStore;

/// The literal type returned by executors: `xla::Literal` with the `pjrt`
/// feature, the host stub otherwise.
#[cfg(feature = "pjrt")]
pub use xla::Literal;
#[cfg(not(feature = "pjrt"))]
pub use executor::Literal;
