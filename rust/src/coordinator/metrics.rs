//! Serving metrics registry: counters + latency histograms, shared across
//! worker threads and rendered by `toma-serve serve` / the e2e example.
//!
//! Latency is tracked in fixed-bucket log-spaced histograms
//! (`util::stats::LatencyHistogram`) with p50/p95/p99 accessors — the
//! micro-batching scheduler's tail-latency acceptance numbers come from
//! here. Cohort [`PlanStats`] aggregate into plain counters via
//! [`Metrics::record_plan_stats`], which the scheduler lane calls with a
//! one-step delta after every cohort step (so `cohort_refresh_all` counts
//! refreshes per cohort step, not per request — the amortization metric).
//!
//! The unified lane front-end (`coordinator::frontend`) exports its
//! lifecycle counters here — `lane_spawned`, `lane_respawned`,
//! `lane_evicted`, `shed_deadline`, `rejected_backpressure`, and since
//! PR 6 the supervision counters `worker_panic`, `lane_unhealthy`,
//! `rejected_unhealthy`, `rejected_backoff`, `retry_attempted`,
//! `quarantined`, `shed_shutdown`, plus `fault_injected` from the
//! deterministic fault injector (`coordinator::fault`) — so
//! `toma-serve serve` and [`Metrics::render`] show lane health (respawn
//! churn, shedding, backpressure, crash containment) next to the request
//! counters. Since PR 7 the tracing pipeline (`coordinator::trace`) adds
//! `lane_degrading` / `lane_recovered`, counted by the online per-lane
//! anomaly detector on flag transitions.
//!
//! Counter and histogram keys are `&'static str` on the hot paths
//! ([`Metrics::inc`] / [`Metrics::add`] / [`Metrics::observe`]): the
//! per-step counting in the drain loops allocates nothing. Dynamically
//! built names go through the `*_owned` variants, which intern the key
//! once on first touch.
//!
//! All lock sites here go through
//! [`lock_unpoisoned`](crate::util::lock_unpoisoned): a worker that
//! panics while counting must not poison the registry and cascade the
//! crash into every other lane. Readers that need counters and
//! histograms to agree take [`Metrics::snapshot`], which holds both
//! locks at once (lock order: counters, then histograms — the only
//! place both are held); [`Metrics::render`] is built on it, so a
//! rendered report is a consistent point-in-time view, not two
//! sequentially-locked halves.
//!
//! **No new control loops on cumulative registries.** Histograms here
//! are lifetime-cumulative: they answer "how did serving go", never
//! "how is this lane doing *now*". Policy feedback consumes signals
//! that decay — each lane's `scheduler::DecayedTail` reservoir, or the
//! trace pipeline's `trace::AnomalyFlags` — as the adaptive batch
//! policy (PR 5) and the anomaly detector (PR 7) do. This registry
//! stays the rendering/acceptance surface.

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::lock_unpoisoned;
use std::time::Duration;

use super::plan_cache::PlanStats;
use crate::util::stats::LatencyHistogram;

/// Summary of one latency histogram (seconds).
#[derive(Clone, Copy, Debug)]
pub struct LatencySummary {
    pub count: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
}

/// Point-in-time view of the whole registry, taken under both locks —
/// counters and histogram summaries are mutually consistent.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub counters: Vec<(String, u64)>,
    pub latencies: Vec<(String, LatencySummary)>,
}

type Key = Cow<'static, str>;

#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<Key, u64>>,
    histograms: Mutex<BTreeMap<Key, LatencyHistogram>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bump a counter by 1. Allocation-free: static keys are borrowed
    /// into the map, never copied.
    pub fn inc(&self, name: &'static str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &'static str, v: u64) {
        let mut c = lock_unpoisoned(&self.counters);
        match c.get_mut(name) {
            Some(slot) => *slot += v,
            None => {
                c.insert(Cow::Borrowed(name), v);
            }
        }
    }

    /// [`Metrics::add`] for dynamically-built names: the key string is
    /// interned once on first touch, later bumps allocate nothing.
    pub fn add_owned(&self, name: &str, v: u64) {
        let mut c = lock_unpoisoned(&self.counters);
        match c.get_mut(name) {
            Some(slot) => *slot += v,
            None => {
                c.insert(Cow::Owned(name.to_string()), v);
            }
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        lock_unpoisoned(&self.counters).get(name).copied().unwrap_or(0)
    }

    pub fn observe(&self, name: &'static str, d: Duration) {
        let mut h = lock_unpoisoned(&self.histograms);
        match h.get_mut(name) {
            Some(hist) => hist.record(d),
            None => {
                h.entry(Cow::Borrowed(name)).or_default().record(d);
            }
        }
    }

    pub fn observe_s(&self, name: &'static str, secs: f64) {
        self.observe(name, Duration::from_secs_f64(secs.max(0.0)));
    }

    /// Aggregate one cohort's plan-cache statistics into counters
    /// (`<prefix>_refresh_all` / `_refresh_weights` / `_reuses`, plus the
    /// PR 8 `_cache_hits` / `_cache_misses` / `_cache_evictions` trio —
    /// emitted only when nonzero, so cache-disabled lanes don't grow
    /// three permanently-zero counters per prefix).
    pub fn record_plan_stats(&self, prefix: &str, s: &PlanStats) {
        self.add_owned(&format!("{prefix}_refresh_all"), s.refresh_all);
        self.add_owned(&format!("{prefix}_refresh_weights"), s.refresh_weights);
        self.add_owned(&format!("{prefix}_reuses"), s.reuses);
        if s.cache_hits > 0 {
            self.add_owned(&format!("{prefix}_cache_hits"), s.cache_hits);
        }
        if s.cache_misses > 0 {
            self.add_owned(&format!("{prefix}_cache_misses"), s.cache_misses);
        }
        if s.cache_evictions > 0 {
            self.add_owned(&format!("{prefix}_cache_evictions"), s.cache_evictions);
        }
    }

    /// One quantile (seconds) of a histogram, `q` in [0, 1]. Rendering /
    /// inspection helper only: these histograms are lifetime-cumulative,
    /// so since PR 5 no policy feedback reads them — the adaptive batch
    /// policy consumes each lane's decayed `scheduler::DecayedTail`, and
    /// lane-health triggers consume `trace::AnomalyFlags`. Do not wire
    /// new control loops to this accessor.
    pub fn quantile_s(&self, name: &str, q: f64) -> Option<f64> {
        let h = lock_unpoisoned(&self.histograms);
        Some(h.get(name)?.quantile_us(q) / 1e6)
    }

    /// Count / mean / p50 / p95 / p99 of a histogram. Single-histogram
    /// reads are internally consistent; use [`Metrics::snapshot`] when
    /// counters and histograms must agree with each other.
    pub fn latency_summary(&self, name: &str) -> Option<LatencySummary> {
        let h = lock_unpoisoned(&self.histograms);
        Some(summarize(h.get(name)?))
    }

    /// Consistent view of every counter and histogram, taken with both
    /// locks held (counters first, then histograms — keep that order if
    /// you ever add another two-lock path).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let counters = lock_unpoisoned(&self.counters);
        let histograms = lock_unpoisoned(&self.histograms);
        MetricsSnapshot {
            counters: counters.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
            latencies: histograms.iter().map(|(k, h)| (k.to_string(), summarize(h))).collect(),
        }
    }

    pub fn render(&self) -> String {
        let snap = self.snapshot();
        let mut out = String::from("-- metrics --\n");
        for (k, v) in &snap.counters {
            out.push_str(&format!("{k:<40} {v}\n"));
        }
        for (k, s) in &snap.latencies {
            out.push_str(&format!(
                "{k:<40} n={} mean={:.3}s p50={:.3}s p95={:.3}s p99={:.3}s\n",
                s.count, s.mean_s, s.p50_s, s.p95_s, s.p99_s
            ));
        }
        out
    }
}

fn summarize(h: &LatencyHistogram) -> LatencySummary {
    LatencySummary {
        count: h.count(),
        mean_s: h.mean_us() / 1e6,
        p50_s: h.quantile_us(0.5) / 1e6,
        p95_s: h.quantile_us(0.95) / 1e6,
        p99_s: h.quantile_us(0.99) / 1e6,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc("req");
        m.add("req", 4);
        assert_eq!(m.counter("req"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn owned_and_static_keys_share_one_namespace() {
        let m = Metrics::new();
        m.add_owned(&format!("{}_total", "req"), 2);
        m.add("req_total", 3); // static bump lands on the interned key
        assert_eq!(m.counter("req_total"), 5);
        let snap = m.snapshot();
        assert_eq!(snap.counters.iter().filter(|(k, _)| k == "req_total").count(), 1);
    }

    #[test]
    fn histogram_summary() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe_s("lat", i as f64 * 0.001);
        }
        let s = m.latency_summary("lat").unwrap();
        assert_eq!(s.count, 100);
        assert!(s.mean_s > 0.04 && s.mean_s < 0.06);
        assert!(s.p50_s <= s.p95_s && s.p95_s <= s.p99_s);
        assert!(m.latency_summary("missing").is_none());
    }

    #[test]
    fn quantile_accessor_matches_summary() {
        let m = Metrics::new();
        for i in 1..=1000 {
            m.observe_s("lat", i as f64 * 1e-4);
        }
        let s = m.latency_summary("lat").unwrap();
        assert_eq!(m.quantile_s("lat", 0.99), Some(s.p99_s));
        assert!(m.quantile_s("missing", 0.5).is_none());
        // Tail quantiles really reach the tail of the distribution.
        assert!(s.p99_s > 0.9 * 0.1, "p99 {}", s.p99_s);
    }

    #[test]
    fn plan_stats_aggregate_into_counters() {
        let m = Metrics::new();
        let s = PlanStats {
            refresh_all: 2,
            refresh_weights: 3,
            reuses: 15,
            ..PlanStats::default()
        };
        m.record_plan_stats("cohort", &s);
        m.record_plan_stats("cohort", &s);
        assert_eq!(m.counter("cohort_refresh_all"), 4);
        assert_eq!(m.counter("cohort_refresh_weights"), 6);
        assert_eq!(m.counter("cohort_reuses"), 30);
        // No cache activity: the cache trio must not appear at all.
        let snap = m.snapshot();
        assert!(snap.counters.iter().all(|(k, _)| !k.contains("cache")), "{snap:?}");
        let c = PlanStats { cache_hits: 5, cache_misses: 2, ..PlanStats::default() };
        m.record_plan_stats("cohort", &c);
        assert_eq!(m.counter("cohort_cache_hits"), 5);
        assert_eq!(m.counter("cohort_cache_misses"), 2);
        assert_eq!(m.counter("cohort_cache_evictions"), 0);
    }

    #[test]
    fn snapshot_is_consistent_and_complete() {
        let m = Metrics::new();
        m.inc("served");
        m.observe_s("lat", 0.25);
        let snap = m.snapshot();
        assert_eq!(snap.counters, vec![("served".to_string(), 1)]);
        assert_eq!(snap.latencies.len(), 1);
        assert_eq!(snap.latencies[0].0, "lat");
        assert_eq!(snap.latencies[0].1.count, 1);
    }

    #[test]
    fn snapshot_under_concurrent_writers_stays_coherent() {
        let m = std::sync::Arc::new(Metrics::new());
        // Writers keep `pairs` and the `lat` histogram in lockstep; a
        // snapshot taken under both locks can only see counter >= count
        // if counters are bumped after the observe — so bump first and
        // assert counter <= histogram count from the read side.
        let mut handles = vec![];
        for _ in 0..4 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..500 {
                    m.observe_s("lat", 0.001);
                    m.inc("pairs");
                }
            }));
        }
        for _ in 0..50 {
            let snap = m.snapshot();
            let pairs = snap
                .counters
                .iter()
                .find(|(k, _)| k == "pairs")
                .map_or(0, |(_, v)| *v);
            let lat = snap.latencies.iter().find(|(k, _)| k == "lat").map_or(0, |(_, s)| s.count);
            assert!(
                pairs <= lat,
                "snapshot saw counter {pairs} ahead of histogram {lat}: torn read"
            );
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = m.snapshot();
        assert_eq!(snap.counters.iter().find(|(k, _)| k == "pairs").unwrap().1, 2000);
    }

    #[test]
    fn render_contains_entries() {
        let m = Metrics::new();
        m.inc("served");
        m.observe_s("lat", 0.1);
        let r = m.render();
        assert!(r.contains("served"));
        assert!(r.contains("lat"));
        assert!(r.contains("p99"));
    }
}
