//! Locality-aware region partitioning (Sec. 4.3.1).
//!
//! * `Stripe` — contiguous row groups: a pure reshape, zero data movement.
//! * `Tile`   — 2-D windows: one permutation each way, best quality.
//! * `Global` — single region (the default ToMA merge scope).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegionMode {
    Global,
    Stripe,
    Tile,
}

impl RegionMode {
    pub fn parse(s: &str) -> Option<RegionMode> {
        match s {
            "global" => Some(RegionMode::Global),
            "stripe" => Some(RegionMode::Stripe),
            "tile" => Some(RegionMode::Tile),
            _ => None,
        }
    }
}

/// A concrete partition of an (h x w) token grid into `regions` parts.
#[derive(Clone, Debug)]
pub struct RegionLayout {
    pub mode: RegionMode,
    pub regions: usize,
    pub grid_h: usize,
    pub grid_w: usize,
    /// token_of[p * n_loc + s] = global token id of slot s in region p.
    token_of: Vec<usize>,
    /// slot_of[token] = (region, slot).
    slot_of: Vec<(usize, usize)>,
}

impl RegionLayout {
    pub fn new(mode: RegionMode, regions: usize, grid_h: usize, grid_w: usize) -> Self {
        let n = grid_h * grid_w;
        let regions = if mode == RegionMode::Global { 1 } else { regions };
        assert!(n % regions == 0, "tokens {n} not divisible by {regions}");
        let n_loc = n / regions;
        let mut token_of = vec![0usize; n];
        match mode {
            RegionMode::Global | RegionMode::Stripe => {
                // Contiguous chunks of the row-major order.
                for (i, t) in token_of.iter_mut().enumerate() {
                    *t = i;
                }
            }
            RegionMode::Tile => {
                let (ty, tx, th, tw) = tile_decomposition(grid_h, grid_w, regions);
                let mut i = 0;
                for by in 0..ty {
                    for bx in 0..tx {
                        for r in 0..th {
                            for c in 0..tw {
                                token_of[i] = (by * th + r) * grid_w + bx * tw + c;
                                i += 1;
                            }
                        }
                    }
                }
            }
        }
        let mut slot_of = vec![(0usize, 0usize); n];
        for p in 0..regions {
            for s in 0..n_loc {
                slot_of[token_of[p * n_loc + s]] = (p, s);
            }
        }
        RegionLayout {
            mode,
            regions,
            grid_h,
            grid_w,
            token_of,
            slot_of,
        }
    }

    pub fn tokens(&self) -> usize {
        self.grid_h * self.grid_w
    }

    pub fn tokens_per_region(&self) -> usize {
        self.tokens() / self.regions
    }

    /// Global token id of (region, slot).
    pub fn token_at(&self, region: usize, slot: usize) -> usize {
        self.token_of[region * self.tokens_per_region() + slot]
    }

    /// (region, slot) of a global token id.
    pub fn slot_of(&self, token: usize) -> (usize, usize) {
        self.slot_of[token]
    }

    /// Split (n, d) row-major features into (regions, n_loc, d), returned
    /// flattened. For Global/Stripe this is a no-op copy.
    pub fn split(&self, x: &[f32], d: usize) -> Vec<f32> {
        let n = self.tokens();
        assert_eq!(x.len(), n * d);
        if self.mode != RegionMode::Tile {
            return x.to_vec();
        }
        let mut out = vec![0.0f32; n * d];
        for (i, &t) in self.token_of.iter().enumerate() {
            out[i * d..(i + 1) * d].copy_from_slice(&x[t * d..(t + 1) * d]);
        }
        out
    }

    /// Inverse of [`split`].
    pub fn join(&self, xs: &[f32], d: usize) -> Vec<f32> {
        let n = self.tokens();
        assert_eq!(xs.len(), n * d);
        if self.mode != RegionMode::Tile {
            return xs.to_vec();
        }
        let mut out = vec![0.0f32; n * d];
        for (i, &t) in self.token_of.iter().enumerate() {
            out[t * d..(t + 1) * d].copy_from_slice(&xs[i * d..(i + 1) * d]);
        }
        out
    }
}

/// Most-square (tiles_y, tiles_x, tile_h, tile_w) with tiles_y*tiles_x == p.
/// Mirrors `toma_jax.RegionSpec.tile_hw`.
pub fn tile_decomposition(grid_h: usize, grid_w: usize, p: usize) -> (usize, usize, usize, usize) {
    let mut best: Option<(usize, usize, usize, usize, usize)> = None;
    for ty in 1..=p {
        if p % ty != 0 {
            continue;
        }
        let tx = p / ty;
        if grid_h % ty != 0 || grid_w % tx != 0 {
            continue;
        }
        let (th, tw) = (grid_h / ty, grid_w / tx);
        let score = th.abs_diff(tw);
        if best.map(|b| score < b.0).unwrap_or(true) {
            best = Some((score, ty, tx, th, tw));
        }
    }
    let (_, ty, tx, th, tw) =
        best.unwrap_or_else(|| panic!("cannot tile {grid_h}x{grid_w} into {p}"));
    (ty, tx, th, tw)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_is_identity() {
        let l = RegionLayout::new(RegionMode::Global, 1, 4, 4);
        let x: Vec<f32> = (0..32).map(|v| v as f32).collect();
        assert_eq!(l.split(&x, 2), x);
        assert_eq!(l.join(&x, 2), x);
    }

    #[test]
    fn stripe_is_contiguous() {
        let l = RegionLayout::new(RegionMode::Stripe, 4, 4, 4);
        for t in 0..16 {
            let (p, s) = l.slot_of(t);
            assert_eq!(p, t / 4);
            assert_eq!(s, t % 4);
        }
    }

    #[test]
    fn tile_split_join_roundtrip() {
        for (g, p) in [(8, 4), (8, 16), (16, 64), (16, 16)] {
            let l = RegionLayout::new(RegionMode::Tile, p, g, g);
            let x: Vec<f32> = (0..g * g * 3).map(|v| v as f32).collect();
            let s = l.split(&x, 3);
            assert_eq!(l.join(&s, 3), x, "g={g} p={p}");
        }
    }

    #[test]
    fn tile_windows_are_spatial() {
        let l = RegionLayout::new(RegionMode::Tile, 16, 8, 8);
        for p in 0..16 {
            let ids: Vec<usize> = (0..4).map(|s| l.token_at(p, s)).collect();
            let rows: Vec<usize> = ids.iter().map(|t| t / 8).collect();
            let cols: Vec<usize> = ids.iter().map(|t| t % 8).collect();
            assert!(rows.iter().max().unwrap() - rows.iter().min().unwrap() <= 1);
            assert!(cols.iter().max().unwrap() - cols.iter().min().unwrap() <= 1);
        }
    }

    #[test]
    fn token_of_is_permutation() {
        let l = RegionLayout::new(RegionMode::Tile, 16, 8, 8);
        let mut ids: Vec<usize> = (0..64).map(|i| l.token_at(i / 4, i % 4)).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn decomposition_prefers_square() {
        assert_eq!(tile_decomposition(16, 16, 16), (4, 4, 4, 4));
        assert_eq!(tile_decomposition(32, 32, 64), (8, 8, 4, 4));
        assert_eq!(tile_decomposition(8, 8, 4), (2, 2, 4, 4));
    }

    #[test]
    fn slot_roundtrip() {
        let l = RegionLayout::new(RegionMode::Tile, 4, 8, 8);
        for t in 0..64 {
            let (p, s) = l.slot_of(t);
            assert_eq!(l.token_at(p, s), t);
        }
    }
}
