//! Admission policy for the micro-batching scheduler: cohort size, the
//! cohort-formation window, queue bounds (backpressure) and admission
//! deadlines (load shedding).

/// Limits governing how a lane forms cohorts and drains its queue.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum cohort size — requests batched into one denoising step.
    pub max_batch: usize,
    /// How long the first request of a new cohort waits for companions
    /// before the cohort starts (the classic batching-window tradeoff:
    /// larger windows raise occupancy, smaller ones bound added latency).
    pub max_queue_wait_s: f64,
    /// Bounded per-lane queue depth; `try_submit` fails fast beyond it
    /// (backpressure), while `submit` blocks.
    pub queue_depth: usize,
    /// Default admission deadline (seconds from submission): a request
    /// still queued after this long is shed with an error instead of
    /// served hopelessly late. Per-request `GenRequest::deadline_s`
    /// overrides it. `None` disables shedding.
    pub deadline_s: Option<f64>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_queue_wait_s: 0.005,
            queue_depth: 256,
            deadline_s: None,
        }
    }
}

impl BatchPolicy {
    /// Policy with a given cohort size cap, defaults elsewhere.
    pub fn with_max_batch(max_batch: usize) -> Self {
        BatchPolicy {
            max_batch,
            ..Default::default()
        }
        .normalized()
    }

    /// Formation windows above this are treated as "wait until the batch
    /// is full": one hour, far beyond any serving cadence, and safely
    /// finite for `Duration::from_secs_f64` (which panics on
    /// non-finite/overflowing input — a lane-killing bug otherwise).
    pub const MAX_QUEUE_WAIT_S: f64 = 3600.0;

    /// Clamp degenerate values to servable bounds.
    pub fn normalized(mut self) -> Self {
        self.max_batch = self.max_batch.max(1);
        self.queue_depth = self.queue_depth.max(1);
        if !(self.max_queue_wait_s >= 0.0) {
            self.max_queue_wait_s = 0.0; // negative or NaN
        }
        if self.max_queue_wait_s > Self::MAX_QUEUE_WAIT_S {
            self.max_queue_wait_s = Self::MAX_QUEUE_WAIT_S; // inf or absurd
        }
        self
    }

    /// Effective admission deadline for a request (request override wins).
    pub fn deadline_for(&self, request_deadline_s: Option<f64>) -> Option<f64> {
        request_deadline_s.or(self.deadline_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_servable() {
        let p = BatchPolicy::default();
        assert!(p.max_batch >= 1);
        assert!(p.queue_depth >= 1);
        assert!(p.max_queue_wait_s >= 0.0);
        assert!(p.deadline_s.is_none());
    }

    #[test]
    fn normalized_clamps_degenerate_values() {
        let p = BatchPolicy {
            max_batch: 0,
            max_queue_wait_s: -1.0,
            queue_depth: 0,
            deadline_s: None,
        }
        .normalized();
        assert_eq!(p.max_batch, 1);
        assert_eq!(p.queue_depth, 1);
        assert_eq!(p.max_queue_wait_s, 0.0);
        // NaN windows clamp too (the `!(x >= 0)` form catches them).
        let p = BatchPolicy {
            max_queue_wait_s: f64::NAN,
            ..Default::default()
        }
        .normalized();
        assert_eq!(p.max_queue_wait_s, 0.0);
        // Infinite / absurd windows clamp to the finite cap instead of
        // later panicking Duration::from_secs_f64 in the lane thread.
        for huge in [f64::INFINITY, 1e30] {
            let p = BatchPolicy {
                max_queue_wait_s: huge,
                ..Default::default()
            }
            .normalized();
            assert_eq!(p.max_queue_wait_s, BatchPolicy::MAX_QUEUE_WAIT_S);
        }
    }

    #[test]
    fn request_deadline_overrides_policy() {
        let p = BatchPolicy {
            deadline_s: Some(1.0),
            ..Default::default()
        };
        assert_eq!(p.deadline_for(None), Some(1.0));
        assert_eq!(p.deadline_for(Some(0.2)), Some(0.2));
        let none = BatchPolicy::default();
        assert_eq!(none.deadline_for(None), None);
        assert_eq!(none.deadline_for(Some(3.0)), Some(3.0));
    }

    #[test]
    fn with_max_batch_sets_cap() {
        assert_eq!(BatchPolicy::with_max_batch(4).max_batch, 4);
        assert_eq!(BatchPolicy::with_max_batch(0).max_batch, 1);
    }
}
