//! GEMM storage-dtype sweep — the mixed-precision tradeoff, measured.
//!
//! Part 1 benches the packed-panel bt-kernel at UViT linear-layer shapes
//! with the `Bᵀ` panels stored in f32 / bf16 / f16 (activations and the
//! accumulator stay f32), reporting median GFLOP/s and the resident panel
//! bytes per dtype — and asserting the bf16 footprint is *exactly* half
//! of f32's, which is the entire point of the storage abstraction.
//!
//! Part 1b (`kernel_dispatch`) pins the PR 5 microkernel seam: the same
//! packed-panel GEMM forced through the scalar reference vs the explicit
//! AVX2+FMA SIMD kernels, per storage dtype — and asserts, on hosts where
//! the SIMD dispatch is supported, that the bf16/f16 widening kernels are
//! strictly faster than scalar (the whole point of hand-vectorizing the
//! widening loads). The rows land in `BENCH_gemm_dtype.json`, so the CI
//! bench-diff gate tracks both kernel paths' trends.
//!
//! Part 1c (`epilogue`, PR 10) times the fused GEMM epilogue (bias+gelu
//! applied per output chunk at write-back) against the seed's two-pass
//! schedule (GEMM, then a serial bias walk, then `ops::gelu`) per storage
//! dtype at the SDXL MLP shape — with an in-bench assert that the fused
//! path wins under the SIMD dispatch. The numeric identity of the two is
//! pinned in `tests/gemm_epilogue.rs`; this tracks the schedule.
//!
//! Part 2 is a Table-6-style latency/accuracy row: the same request
//! generated end-to-end through the per-request host engine with f32 vs
//! bf16 vs f16 weight panels, with the quality deltas
//! (`quality::precision_delta`) alongside the median step latency.
//!
//! Emits `BENCH_gemm_dtype.json` (target name `gemm_dtype`) containing
//! only the Part-1/1b/1c kernel rows — that file is hard-gated by CI's
//! bench-diff like table6. The Part-2 end-to-end generation timings are
//! wall-clock and scheduler-noise-prone on shared runners, so they print
//! to stdout but are deliberately kept out of the gated JSON (same
//! policy as serve_sweep).

use std::sync::Arc;

use toma::bench::Runner;
use toma::coordinator::scheduler::{HostEngine, DEFAULT_TAU};
use toma::coordinator::{EngineConfig, GenRequest};
use toma::model::HostUVit;
use toma::quality::{precision_delta, FeatureExtractor};
use toma::report::{fmt_secs, Table};
use toma::runtime::ModelInfo;
use toma::tensor::element::StorageDtype;
use toma::tensor::gemm::{Epilogue, Panels};
use toma::tensor::kernel::{self, Dispatch};
use toma::tensor::ops;
use toma::util::Pcg64;

/// UViT linear-layer shapes at width 512 (m = tokens, k = d_in, n = d_out).
const SHAPES: [(&str, usize, usize, usize); 3] = [
    ("qkv", 256, 512, 1536),
    ("proj", 256, 512, 512),
    ("mlp2", 256, 2048, 512),
];

fn main() {
    let mut runner = Runner::from_args();
    runner.note("kernel_dispatch", kernel::report());
    println!("kernel dispatch: {}", kernel::report());
    let mut rng = Pcg64::new(0xD7E);

    // --- Part 1: kernel sweep over storage dtypes. ---------------------
    let mut table = Table::new("GEMM dtype sweep — packed-panel bt-kernel, f32 accumulate")
        .headers(&["Shape", "Dtype", "Median", "GFLOP/s", "Panel bytes"]);
    for (name, m, k, n) in SHAPES {
        let a = rng.normal_vec(m * k);
        let scale = 1.0 / (k as f32).sqrt();
        let w: Vec<f32> = rng.normal_vec(k * n).into_iter().map(|v| v * scale).collect();
        let flops = 2.0 * (m * k * n) as f64;
        let mut f32_bytes = 0usize;
        for dtype in StorageDtype::ALL {
            let panels = Panels::pack(&w, k, n, dtype);
            match dtype {
                StorageDtype::F32 => f32_bytes = panels.bytes(),
                StorageDtype::Bf16 => assert_eq!(
                    panels.bytes() * 2,
                    f32_bytes,
                    "bf16 packed panels must be exactly half the f32 footprint"
                ),
                StorageDtype::F16 => assert_eq!(panels.bytes() * 2, f32_bytes),
            }
            let mut c = vec![0.0f32; m * n];
            let label = format!("gemm_bt_{name}_{dtype}");
            let med = runner.bench(&label, || {
                panels.matmul_bt_into(&a, &mut c, m, k, n);
                std::hint::black_box(&c);
            });
            if med > 0.0 {
                table.row(vec![
                    format!("{name} {m}x{k}x{n}"),
                    dtype.to_string(),
                    fmt_secs(med),
                    format!("{:.2}", flops / med / 1e9),
                    format!("{}", panels.bytes()),
                ]);
            }
        }
    }
    println!("\n{}", table.render());

    // --- Part 1b: kernel_dispatch — scalar vs explicit SIMD per dtype. --
    let mut kd = Table::new("kernel_dispatch — scalar vs SIMD microkernel (proj 256x512x512)")
        .headers(&["Dtype", "Kernel", "Median", "GFLOP/s"]);
    let (m, k, n) = (256usize, 512usize, 512usize);
    let a = rng.normal_vec(m * k);
    let scale = 1.0 / (k as f32).sqrt();
    let w: Vec<f32> = rng.normal_vec(k * n).into_iter().map(|v| v * scale).collect();
    let flops = 2.0 * (m * k * n) as f64;
    for dtype in StorageDtype::ALL {
        let panels = Panels::pack(&w, k, n, dtype);
        let mut medians = std::collections::BTreeMap::new();
        for (disp, tag) in [(Dispatch::Scalar, "scalar"), (Dispatch::Avx2Fma, "simd")] {
            if !disp.supported() {
                continue;
            }
            let mut c = vec![0.0f32; m * n];
            let label = format!("kernel_dispatch_{dtype}_{tag}");
            let med = runner.bench(&label, || {
                panels.matmul_bt_into_as(disp, &a, &mut c, m, k, n);
                std::hint::black_box(&c);
            });
            if med > 0.0 {
                kd.row(vec![
                    dtype.to_string(),
                    tag.into(),
                    fmt_secs(med),
                    format!("{:.2}", flops / med / 1e9),
                ]);
                medians.insert(tag, med);
            }
        }
        // The acceptance pin: where the SIMD dispatch runs, the
        // hand-vectorized widening kernels must beat the scalar path at
        // model shapes (f32 is reported but not asserted — it is the
        // bit-identity path, not the bandwidth play).
        if let (Some(&sc), Some(&si)) = (medians.get("scalar"), medians.get("simd")) {
            if dtype != StorageDtype::F32 {
                assert!(
                    si < sc,
                    "{dtype}: SIMD widening kernel must beat scalar ({si:.3e}s vs {sc:.3e}s)"
                );
            }
        }
    }
    println!("\n{}", kd.render());

    // --- Part 1c: epilogue — fused write-back vs the seed's two-pass. --
    // The SDXL MLP shape (m = 4096 tokens, k = 512, n = 2048) with the
    // bias+gelu epilogue: the fused path applies the epilogue per output
    // chunk inside the parallel GEMM write-back (cache-hot, on the pool
    // threads); the two-pass reference replays the seed call sites —
    // GEMM, then a serial bias broadcast, then `ops::gelu` over the full
    // 32 MiB C. Same elementwise math, bitwise-identical result (pinned
    // in tests/gemm_epilogue.rs); this measures the schedule change.
    let mut et = Table::new("epilogue — fused vs two-pass, bias+gelu (mlp1 4096x512x2048)")
        .headers(&["Dtype", "Variant", "Median", "eff GB/s"]);
    let (m, k, n) = (4096usize, 512usize, 2048usize);
    let a = rng.normal_vec(m * k);
    let scale = 1.0 / (k as f32).sqrt();
    let w: Vec<f32> = rng.normal_vec(k * n).into_iter().map(|v| v * scale).collect();
    let bias = rng.normal_vec(n);
    for dtype in StorageDtype::ALL {
        let panels = Panels::pack(&w, k, n, dtype);
        // Ideal streamed bytes: A + packed panels + C written once. The
        // two-pass legs move 2 extra C-sized passes on top of this, which
        // is exactly the gap being measured.
        let bytes = (4 * m * k + panels.bytes() + 4 * m * n) as f64;
        let mut c = vec![0.0f32; m * n];
        let mut medians = std::collections::BTreeMap::new();
        for tag in ["fused", "twopass"] {
            let label = format!("epilogue_{dtype}_{tag}");
            let med = runner.bench(&label, || {
                if tag == "fused" {
                    let ep = Epilogue::BiasGelu(&bias);
                    panels.matmul_bt_into_ep(&a, &mut c, m, k, n, ep);
                } else {
                    panels.matmul_bt_into(&a, &mut c, m, k, n);
                    for row in c.chunks_mut(n) {
                        for (cv, bv) in row.iter_mut().zip(&bias) {
                            *cv += bv;
                        }
                    }
                    ops::gelu(&mut c);
                }
                std::hint::black_box(&c);
            });
            if med > 0.0 {
                et.row(vec![
                    dtype.to_string(),
                    tag.into(),
                    fmt_secs(med),
                    format!("{:.2}", bytes / med / 1e9),
                ]);
                medians.insert(tag, med);
            }
        }
        if let (Some(&fu), Some(&tp)) = (medians.get("fused"), medians.get("twopass")) {
            runner.note(&format!("epilogue_{dtype}_speedup"), &format!("{:.2}x", tp / fu));
            // The PR 10 acceptance pin: under the SIMD dispatch the fused
            // epilogue must strictly beat the seed's two-pass schedule at
            // the SDXL MLP shape.
            if kernel::active() == Dispatch::Avx2Fma {
                assert!(
                    fu < tp,
                    "{dtype}: fused epilogue must beat two-pass ({fu:.3e}s vs {tp:.3e}s)"
                );
            }
        }
    }
    println!("\n{}", et.render());

    // --- Part 2: table6-style f32-vs-half latency/accuracy row. --------
    // Timed on a separate un-JSON'd runner: these are wall-clock e2e
    // generations, which the CI gate's own policy keeps warn-only — only
    // the Part-1 kernel medians land in the hard-gated BENCH file.
    let mut e2e = Runner {
        filter: runner.filter.clone(),
        min_time_s: runner.min_time_s,
        min_iters: runner.min_iters,
        max_iters: runner.max_iters,
        results: vec![],
        json: None,
        notes: vec![],
    };
    let info = ModelInfo::synthetic("uvit_dtype", 8, 2, 64, 4, 4, 8);
    let master = Arc::new(HostUVit::synthetic(&info, 2, 0x5EED));
    let mut cfg = EngineConfig::new("uvit_dtype", "toma", Some(0.5));
    cfg.steps = 6;
    let req = GenRequest::new("a photo of a capy... a cat", 7);
    let fx = FeatureExtractor::new(info.channels * info.tokens, 64, 11);

    let mut rows: Vec<(StorageDtype, f64, Vec<f32>)> = vec![];
    for dtype in StorageDtype::ALL {
        let engine = HostEngine::new(
            master.clone(),
            cfg.clone().with_storage(dtype),
            4,
            DEFAULT_TAU,
        )
        .expect("host engine");
        let mut latent = vec![];
        let label = format!("e2e_generate_{dtype}");
        let med = e2e.bench(&label, || {
            latent = engine.generate(&req).expect("generate").latent;
        });
        rows.push((dtype, med, latent));
    }
    let f32_row = rows.iter().find(|r| r.0 == StorageDtype::F32).expect("f32 row");
    let reference = f32_row.2.clone();
    let f32_med = f32_row.1;
    if reference.is_empty() {
        return; // e2e cases filtered out (`--filter gemm_bt` style runs)
    }
    let mut t6 = Table::new("f32 vs half storage — latency / accuracy (host engine, 6 steps)")
        .headers(&["Dtype", "Median gen", "vs f32", "DINO-d", "MSE", "max|d|"]);
    for (dtype, med, latent) in &rows {
        if e2e.get(&format!("e2e_generate_{dtype}")).is_none() {
            continue; // filtered out
        }
        let d = precision_delta(&fx, &reference, latent);
        t6.row(vec![
            dtype.to_string(),
            fmt_secs(*med),
            if f32_med > 0.0 {
                format!("{:.2}x", f32_med / med.max(1e-12))
            } else {
                "—".into()
            },
            format!("{:.4}", d.dino_delta),
            format!("{:.3}", d.mse),
            format!("{:.3}", d.max_abs),
        ]);
        if *dtype == StorageDtype::F32 {
            assert_eq!(d.mse, 0.0, "f32 vs f32 must be bit-identical");
        } else {
            assert!(
                latent.iter().all(|v| v.is_finite()),
                "{dtype} trajectory must stay finite"
            );
        }
    }
    println!("\n{}", t6.render());
    println!(
        "note: half panels halve the packed-operand bytes the k-panel sweep\n\
         streams; the win grows with k (memory-bound regime). Accuracy deltas\n\
         are latent-space proxies (quality::precision_delta) vs the f32 run."
    );
}
