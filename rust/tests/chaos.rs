//! Chaos acceptance tests for the PR 6 supervision layer, driven through
//! the public API against **both** `LaneJob` instantiations — the
//! `Scheduler`'s cohort lane (real host backend, artifact-free) and the
//! `Server`'s engine lane (init-failed engines still probe faults, so the
//! lifecycle runs artifact-free too). Every scenario is deterministic:
//! faults fire on injector schedules (exact probe counts or poisoned
//! seeds), never timers, and no test sleeps on wall clock.
//!
//! The behaviors under test: a worker panic surfaces as a retryable error
//! *completion* (never a dropped sender), dead lanes respawn
//! generation-checked, a poison request is quarantined after K strikes
//! while innocents are transparently re-run, a crash storm opens the
//! circuit breaker exactly once, a half-open probe closes it again on a
//! healthy serve, and graceful drain answers queued jobs with explicit
//! "shutting down" completions.

use std::sync::Arc;

use toma::coordinator::scheduler::{BatchPolicy, HostBackend, DEFAULT_TAU};
use toma::coordinator::{
    Completion, EngineConfig, FaultKind, FaultPlan, GenRequest, RetryPolicy, Scheduler, Server,
    SupervisionPolicy,
};
use toma::model::HostUVit;
use toma::runtime::ModelInfo;
use toma::toma::plan::ReuseSchedule;

const REGIONS: usize = 4;

fn model() -> Arc<HostUVit> {
    let info = ModelInfo::synthetic("uvit_chaos", 4, 2, 16, 2, 3, 5);
    Arc::new(HostUVit::synthetic(&info, 2, 4242))
}

fn toma_cfg(steps: usize) -> EngineConfig {
    let mut cfg = EngineConfig::new("uvit_chaos", "toma", Some(0.5));
    cfg.steps = steps;
    cfg.select_mode = "tile".to_string();
    cfg.schedule = ReuseSchedule::default();
    cfg
}

fn host_scheduler(plan: FaultPlan) -> Scheduler {
    let m = model();
    Scheduler::new(
        BatchPolicy {
            max_batch: 4,
            max_queue_wait_s: 0.05,
            ..Default::default()
        },
        move |c: &EngineConfig| HostBackend::boxed(m.clone(), c.clone(), REGIONS, DEFAULT_TAU),
    )
    .with_faults(plan)
}

/// An artifact-free server: every lane spawns, fails engine init, and
/// serves every job a clean "engine init failed" completion — a live lane
/// whose dequeue path still probes the fault injector.
fn dead_dir_server(plan: FaultPlan) -> Server {
    Server::new(std::env::temp_dir().join("toma_chaos_no_artifacts"), 1).with_faults(plan)
}

fn err_text(c: &Completion) -> String {
    c.result
        .as_ref()
        .err()
        .map(|e| e.to_string())
        .unwrap_or_default()
}

// ---------------------------------------------------------------- scheduler

/// A poisoned cohort member panics the lane; the poison is quarantined
/// after two strikes while the innocents caught in the blast radius are
/// transparently re-run to successful completions.
#[test]
fn scheduler_poison_quarantined_innocents_recovered() {
    let sched = host_scheduler(FaultPlan::default().poison(666, FaultKind::Panic));
    let cfg = toma_cfg(3);
    let reqs = vec![
        GenRequest::new("a", 1),
        GenRequest::new("b", 2),
        GenRequest::new("poison", 666),
    ];
    let comps = sched.run_batch_retry(
        &cfg,
        reqs,
        RetryPolicy {
            max_attempts: 8,
            quarantine_strikes: 2,
        },
    );
    assert!(comps[0].result.is_ok(), "innocent a: {}", err_text(&comps[0]));
    assert!(comps[1].result.is_ok(), "innocent b: {}", err_text(&comps[1]));
    let poison_err = err_text(&comps[2]);
    assert!(poison_err.contains("quarantined"), "poison: {poison_err}");
    // Join lane threads before reading counters: a dying worker records
    // its panic *after* sending the completion.
    sched.shutdown();
    assert_eq!(sched.metrics.counter("quarantined"), 1);
    assert!(sched.metrics.counter("worker_panic") >= 2);
    assert!(sched.metrics.counter("retry_attempted") >= 1);
    assert!(sched.metrics.counter("lane_respawned") >= 1);
    assert_eq!(
        sched.metrics.counter("lane_unhealthy"),
        0,
        "quarantine must contain the poison before the breaker trips"
    );
}

/// Resubmitting a crash-looping request past the respawn budget opens the
/// circuit breaker exactly once; afterwards submissions fail fast with an
/// "unhealthy" completion instead of burning respawns.
#[test]
fn scheduler_crash_storm_opens_breaker_and_fails_fast() {
    let sched = host_scheduler(FaultPlan::default().poison(666, FaultKind::Panic))
        .with_supervision(SupervisionPolicy {
            backoff_base_s: 0.0,
            backoff_max_s: 2.0,
            respawn_budget: 2,
            breaker_probe_s: 3600.0,
        });
    let cfg = toma_cfg(3);
    let mut opened = false;
    for _ in 0..32 {
        let rx = sched.submit(&cfg, GenRequest::new("poison", 666));
        let Ok(c) = rx.recv() else { continue };
        assert!(c.result.is_err(), "poison must never be served");
        if err_text(&c).contains("unhealthy") {
            opened = true;
            break;
        }
    }
    assert!(opened, "crash storm must trip the breaker");
    sched.shutdown();
    assert_eq!(sched.metrics.counter("lane_unhealthy"), 1, "breaker opens exactly once");
    assert!(sched.metrics.counter("rejected_unhealthy") >= 1);
    assert!(sched.metrics.counter("worker_panic") >= 2);
}

/// With an immediate probe cool-down, the breaker half-opens after the
/// crash and a healthy serve closes it: innocents recover the lane.
#[test]
fn scheduler_breaker_half_open_probe_recovers_on_healthy_serve() {
    let sched = host_scheduler(FaultPlan::default().poison(666, FaultKind::Panic))
        .with_supervision(SupervisionPolicy {
            backoff_base_s: 0.0,
            backoff_max_s: 2.0,
            respawn_budget: 1,    // the first death opens the breaker
            breaker_probe_s: 0.0, // probes allowed immediately
        });
    let cfg = toma_cfg(3);
    let rx = sched.submit(&cfg, GenRequest::new("poison", 666));
    let c = rx.recv().expect("panic must answer with a completion");
    assert!(err_text(&c).contains("worker panicked"), "{}", err_text(&c));
    // The corpse may take one stale hop to evict; within a few attempts a
    // half-open probe must respawn the lane and serve an innocent.
    let mut served = false;
    for attempt in 0..4u64 {
        let rx = sched.submit(&cfg, GenRequest::new("innocent", attempt));
        if let Ok(c) = rx.recv() {
            if c.result.is_ok() {
                served = true;
                break;
            }
        }
    }
    assert!(served, "half-open probe must let an innocent close the breaker");
    sched.shutdown();
    assert_eq!(sched.metrics.counter("lane_unhealthy"), 1, "the crash opened the breaker");
    assert_eq!(sched.metrics.counter("rejected_unhealthy"), 0, "probes, not rejections");
}

/// An injected error-return fails the cohort with a retryable error but
/// leaves the lane alive; the retry layer recovers on the same lane.
#[test]
fn scheduler_injected_error_recovered_without_respawn() {
    let sched =
        host_scheduler(FaultPlan::default().at("scheduler.step", 1, FaultKind::ErrorReturn));
    let reqs = vec![GenRequest::new("x", 9)];
    let comps = sched.run_batch_retry(&toma_cfg(3), reqs, RetryPolicy::default());
    assert!(comps[0].result.is_ok(), "{}", err_text(&comps[0]));
    assert_eq!(sched.metrics.counter("retry_attempted"), 1);
    assert_eq!(sched.metrics.counter("fault_injected"), 1);
    assert_eq!(sched.metrics.counter("worker_panic"), 0);
    assert_eq!(sched.metrics.counter("lane_respawned"), 0);
    sched.shutdown();
}

/// Graceful drain: after `begin_drain`, un-admitted jobs get explicit,
/// counted "shutting down" completions — never a bare disconnect.
#[test]
fn scheduler_drain_answers_queued_jobs() {
    let sched = host_scheduler(FaultPlan::default());
    let cfg = toma_cfg(2);
    let pre = sched.run_batch(&cfg, vec![GenRequest::new("pre", 1)]);
    assert!(pre[0].result.is_ok());
    sched.begin_drain();
    let rx = sched.submit(&cfg, GenRequest::new("post", 2));
    let c = rx.recv().expect("drain must answer");
    assert!(err_text(&c).contains("shutting down"), "{}", err_text(&c));
    assert_eq!(sched.metrics.counter("shed_shutdown"), 1);
    sched.shutdown();
}

// ------------------------------------------------------------------- server

/// A server worker panic (injector-driven at the dequeue probe) surfaces
/// as an error completion and the lane respawns: a later innocent gets
/// the healthy lane's answer.
#[test]
fn server_injected_panic_answers_and_respawns() {
    let server = dead_dir_server(FaultPlan::default().poison(666, FaultKind::Panic));
    let cfg = EngineConfig::new("uvit_none", "baseline", None);
    let rx = server.submit(&cfg, GenRequest::new("poison", 666));
    let c = rx.recv().expect("panic must answer with a completion");
    assert!(err_text(&c).contains("worker panicked"), "{}", err_text(&c));
    // Respawn: an innocent must reach a live lane (its init-failed worker
    // answers with the engine error) within a few attempts.
    let mut served = false;
    for attempt in 0..4u64 {
        let rx = server.submit(&cfg, GenRequest::new("innocent", attempt));
        if let Ok(c) = rx.recv() {
            if err_text(&c).contains("engine init failed") {
                served = true;
                break;
            }
        }
    }
    assert!(served, "lane must respawn after the injected panic");
    server.shutdown();
    assert!(server.metrics.counter("worker_panic") >= 1);
    assert!(server.metrics.counter("lane_respawned") >= 1);
}

/// Same poison-pill containment on the server lane: quarantine the
/// poison, transparently re-serve the innocents.
#[test]
fn server_poison_quarantined_innocents_recovered() {
    let server = dead_dir_server(FaultPlan::default().poison(666, FaultKind::Panic));
    let cfg = EngineConfig::new("uvit_none", "baseline", None);
    let comps = server.run_batch_retry(
        &cfg,
        vec![
            GenRequest::new("a", 1),
            GenRequest::new("b", 2),
            GenRequest::new("poison", 666),
        ],
        RetryPolicy {
            max_attempts: 8,
            quarantine_strikes: 2,
        },
    );
    for c in &comps[..2] {
        assert!(
            err_text(c).contains("engine init failed"),
            "innocent must reach a live lane: {}",
            err_text(c)
        );
    }
    assert!(err_text(&comps[2]).contains("quarantined"), "{}", err_text(&comps[2]));
    server.shutdown();
    assert_eq!(server.metrics.counter("quarantined"), 1);
    assert!(server.metrics.counter("worker_panic") >= 2);
}

/// A one-shot injected error on the server dequeue is retried
/// transparently; the lane survives (no panic, no eviction).
#[test]
fn server_injected_error_recovered_without_lane_death() {
    let server = dead_dir_server(FaultPlan::default().at("server.step", 1, FaultKind::ErrorReturn));
    let cfg = EngineConfig::new("uvit_none", "baseline", None);
    let reqs = vec![GenRequest::new("x", 1)];
    let comps = server.run_batch_retry(&cfg, reqs, RetryPolicy::default());
    assert!(err_text(&comps[0]).contains("engine init failed"), "{}", err_text(&comps[0]));
    assert_eq!(server.metrics.counter("retry_attempted"), 1);
    assert_eq!(server.metrics.counter("worker_panic"), 0);
    assert_eq!(server.metrics.counter("lane_evicted"), 0);
    server.shutdown();
}

/// Server-side graceful drain mirrors the scheduler's: explicit counted
/// completions for queued jobs once the drain flag flips.
#[test]
fn server_drain_answers_queued_jobs() {
    let server = dead_dir_server(FaultPlan::default());
    let cfg = EngineConfig::new("uvit_none", "baseline", None);
    let pre = server.run_batch(&cfg, vec![GenRequest::new("pre", 1)]);
    assert!(err_text(&pre[0]).contains("engine init failed"));
    server.begin_drain();
    let rx = server.submit(&cfg, GenRequest::new("post", 2));
    let c = rx.recv().expect("drain must answer");
    assert!(err_text(&c).contains("shutting down"), "{}", err_text(&c));
    assert_eq!(server.metrics.counter("shed_shutdown"), 1);
    server.shutdown();
}

/// The `TOMA_FAULTS`-style rate schedule in its always-safe default
/// (slow-step only) leaves results correct end to end: a full batch under
/// a 20% latency-jitter schedule completes every request successfully.
#[test]
fn rate_slow_faults_never_change_results() {
    let sched = host_scheduler(FaultPlan::default().with_rate(0.2, 42));
    let comps = sched.run_batch(&toma_cfg(4), (0..4).map(|i| GenRequest::new("r", i)).collect());
    for c in &comps {
        assert!(c.result.is_ok(), "{}", err_text(c));
    }
    assert_eq!(sched.metrics.counter("requests_ok"), 4);
    assert_eq!(sched.metrics.counter("worker_panic"), 0);
    sched.shutdown();
}
