//! Criterion-style micro-benchmark harness (the vendored crate set has no
//! `criterion`): warmup, timed iterations, median/p10/p90 with outlier
//! trimming, and a `--filter` / `--quick` aware runner for `cargo bench`
//! targets (`harness = false`).

use std::time::Instant;

use crate::util::stats;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
}

impl BenchResult {
    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>12} median  {:>12} p90  ({} iters)",
            self.name,
            crate::report::fmt_secs(self.median_s),
            crate::report::fmt_secs(self.p90_s),
            self.iters
        )
    }
}

/// Benchmark runner configured from CLI args.
pub struct Runner {
    pub filter: Option<String>,
    /// Minimum sampling time per case, seconds.
    pub min_time_s: f64,
    pub min_iters: usize,
    pub max_iters: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Runner {
    fn default() -> Self {
        Runner::new()
    }
}

impl Runner {
    pub fn new() -> Self {
        Runner {
            filter: None,
            min_time_s: 0.5,
            min_iters: 5,
            max_iters: 1000,
            results: vec![],
        }
    }

    /// Configure from `cargo bench -- [filter] [--quick]` style args.
    pub fn from_args() -> Self {
        let mut r = Runner::new();
        for a in std::env::args().skip(1) {
            match a.as_str() {
                "--quick" => {
                    r.min_time_s = 0.05;
                    r.min_iters = 2;
                    r.max_iters = 20;
                }
                "--bench" | "--exact" => {}
                s if !s.starts_with('-') => r.filter = Some(s.to_string()),
                _ => {}
            }
        }
        if std::env::var("TOMA_BENCH_QUICK").is_ok() {
            r.min_time_s = 0.05;
            r.min_iters = 2;
            r.max_iters = 20;
        }
        r
    }

    pub fn should_run(&self, name: &str) -> bool {
        self.filter
            .as_ref()
            .map(|f| name.contains(f.as_str()))
            .unwrap_or(true)
    }

    /// Time `f`, printing and recording the result. Returns median seconds.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> f64 {
        if !self.should_run(name) {
            return 0.0;
        }
        // Warmup: one untimed call plus enough to estimate cost.
        let t0 = Instant::now();
        f();
        let first = t0.elapsed().as_secs_f64();
        let target_iters = ((self.min_time_s / first.max(1e-9)) as usize)
            .clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(target_iters);
        for _ in 0..target_iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        // Trim top/bottom 10% against scheduler noise.
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let trim = samples.len() / 10;
        let trimmed = &samples[trim..samples.len() - trim.min(samples.len() - 1)];
        let result = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            median_s: stats::median(trimmed),
            mean_s: stats::mean(trimmed),
            p10_s: stats::percentile(&samples, 10.0),
            p90_s: stats::percentile(&samples, 90.0),
        };
        println!("{}", result.summary());
        let med = result.median_s;
        self.results.push(result);
        med
    }

    /// Look up a recorded result by exact name.
    pub fn get(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_result() {
        let mut r = Runner::new();
        r.min_time_s = 0.01;
        r.max_iters = 10;
        let med = r.bench("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(med >= 0.0);
        assert_eq!(r.results.len(), 1);
        assert!(r.get("spin").is_some());
        assert!(r.get("missing").is_none());
    }

    #[test]
    fn filter_skips() {
        let mut r = Runner::new();
        r.filter = Some("match".into());
        assert!(r.should_run("a_match_b"));
        assert!(!r.should_run("other"));
        let ran = std::cell::Cell::new(false);
        r.bench("other", || ran.set(true));
        assert!(!ran.get());
        assert!(r.results.is_empty());
    }

    #[test]
    fn ordering_sane_for_different_costs() {
        let mut r = Runner::new();
        r.min_time_s = 0.02;
        r.max_iters = 50;
        // black_box the *bounds* so the compiler cannot constant-fold the
        // loops away in release mode.
        let fast = r.bench("fast", || {
            let n = std::hint::black_box(100u64);
            std::hint::black_box((0..n).map(|x| x.wrapping_mul(x)).sum::<u64>());
        });
        let slow = r.bench("slow", || {
            let n = std::hint::black_box(1_000_000u64);
            std::hint::black_box((0..n).map(|x| x.wrapping_mul(x)).sum::<u64>());
        });
        assert!(slow > fast);
    }
}
