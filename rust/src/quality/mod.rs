//! Quality-proxy metrics (see DESIGN.md §substitutions).
//!
//! The paper scores generated *images* with pretrained networks (DINO,
//! CLIP, Inception/FID). Without those weights we score generated *latents*
//! with a fixed random-projection feature extractor — a universal,
//! seed-deterministic embedding that preserves the metrics' ordering
//! semantics: identical outputs score perfectly, degradation grows with
//! merge aggressiveness, and distribution shift inflates the Fréchet
//! distance.

pub mod features;
pub mod fid;

pub use features::FeatureExtractor;
pub use fid::frechet_distance;

/// DINO-proxy: 1 - mean cosine similarity between the feature embeddings of
/// a reference latent and a candidate latent (paper's DINO "delta"; 0 =
/// identical, higher = worse).
pub fn dino_proxy(fx: &FeatureExtractor, reference: &[f32], candidate: &[f32]) -> f64 {
    assert_eq!(reference.len(), candidate.len());
    let a = fx.embed(reference);
    let b = fx.embed(candidate);
    1.0 - cosine(&a, &b)
}

/// CLIP-proxy: scaled cosine alignment between the latent's features and
/// the conditioning embedding's features (higher = better aligned). The
/// paper's CLIP-T sits around ~30; we use the same x100/3 scaling so tables
/// are visually comparable.
pub fn clip_proxy(fx: &FeatureExtractor, latent: &[f32], cond: &[f32]) -> f64 {
    let a = fx.embed(latent);
    let b = fx.embed_any(cond);
    (cosine(&a, &b) + 1.0) * 0.5 * 33.0
}

/// Pixel-space mean-squared error (the App. F ablation metric), scaled by
/// 1e4 to land in the paper's integer range.
pub fn mse(reference: &[f32], candidate: &[f32]) -> f64 {
    assert_eq!(reference.len(), candidate.len());
    let s: f64 = reference
        .iter()
        .zip(candidate)
        .map(|(a, b)| {
            let d = (*a - *b) as f64;
            d * d
        })
        .sum();
    s / reference.len() as f64 * 1e4
}

/// Write a grayscale PGM preview of a latent (C, H, W): channels are
/// averaged and min-max normalized — the qualitative-figure stand-in
/// (Fig. 1 / 5-8) for environments without a VAE decoder.
pub fn write_pgm_preview(
    latent: &[f32],
    channels: usize,
    hw: usize,
    path: &str,
) -> crate::util::error::Result<()> {
    use std::io::Write;
    let n = hw * hw;
    crate::ensure!(latent.len() == channels * n, "latent size mismatch");
    let mut gray = vec![0.0f32; n];
    for c in 0..channels {
        for p in 0..n {
            gray[p] += latent[c * n + p] / channels as f32;
        }
    }
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for v in &gray {
        lo = lo.min(*v);
        hi = hi.max(*v);
    }
    let scale = if hi > lo { 255.0 / (hi - lo) } else { 0.0 };
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "P5\n{hw} {hw}\n255")?;
    let bytes: Vec<u8> = gray
        .iter()
        .map(|v| ((v - lo) * scale).clamp(0.0, 255.0) as u8)
        .collect();
    f.write_all(&bytes)?;
    Ok(())
}

/// Quality deltas between two precision trajectories of the *same*
/// request (same seed/prompt/config, different weight-panel storage
/// dtype) — the accuracy column of the mixed-precision tradeoff that the
/// `gemm_dtype` bench and the Table-6-style f32-vs-bf16 row report.
#[derive(Clone, Copy, Debug, Default)]
pub struct PrecisionDelta {
    /// DINO-proxy distance between the two latents (0 = identical).
    pub dino_delta: f64,
    /// Latent MSE (scaled 1e4, same convention as [`mse`]).
    pub mse: f64,
    /// Max absolute elementwise difference.
    pub max_abs: f64,
}

/// Score how far a `candidate` latent (half-precision storage) drifted
/// from its `reference` latent (f32 storage). Zero across the board iff
/// the trajectories are bit-identical.
pub fn precision_delta(
    fx: &FeatureExtractor,
    reference: &[f32],
    candidate: &[f32],
) -> PrecisionDelta {
    assert_eq!(reference.len(), candidate.len());
    let max_abs = reference
        .iter()
        .zip(candidate)
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0f64, f64::max);
    PrecisionDelta {
        dino_delta: dino_proxy(fx, reference, candidate),
        mse: mse(reference, candidate),
        max_abs,
    }
}

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        dot += (*x as f64) * (*y as f64);
        na += (*x as f64).powi(2);
        nb += (*y as f64).powi(2);
    }
    dot / (na.sqrt() * nb.sqrt() + 1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn dino_zero_for_identical() {
        let fx = FeatureExtractor::new(64, 32, 7);
        let x = Pcg64::new(0).normal_vec(64);
        assert!(dino_proxy(&fx, &x, &x) < 1e-6);
    }

    #[test]
    fn dino_grows_with_perturbation() {
        let fx = FeatureExtractor::new(256, 64, 7);
        let mut rng = Pcg64::new(1);
        let x = rng.normal_vec(256);
        let mk = |noise: f32, rng: &mut Pcg64| -> Vec<f32> {
            x.iter().map(|v| v + noise * rng.normal()).collect()
        };
        let small = dino_proxy(&fx, &x, &mk(0.1, &mut rng));
        let large = dino_proxy(&fx, &x, &mk(1.0, &mut rng));
        assert!(small < large, "{small} vs {large}");
        assert!(small > 0.0);
    }

    #[test]
    fn mse_basic() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((mse(&[0.0], &[0.1]) - 100.0).abs() < 1e-3);
    }

    #[test]
    fn precision_delta_zero_iff_identical_and_grows_with_noise() {
        let fx = FeatureExtractor::new(128, 32, 7);
        let mut rng = Pcg64::new(3);
        let x = rng.normal_vec(128);
        let same = precision_delta(&fx, &x, &x);
        assert_eq!(same.mse, 0.0);
        assert_eq!(same.max_abs, 0.0);
        assert!(same.dino_delta < 1e-6);
        // Simulated storage rounding: small perturbation => small deltas,
        // larger perturbation => strictly larger deltas.
        let mk = |noise: f32, rng: &mut Pcg64| -> Vec<f32> {
            x.iter().map(|v| v + noise * rng.normal()).collect()
        };
        let small = precision_delta(&fx, &x, &mk(0.01, &mut rng));
        let large = precision_delta(&fx, &x, &mk(0.5, &mut rng));
        assert!(small.mse > 0.0 && small.mse < large.mse);
        assert!(small.max_abs < large.max_abs);
    }

    #[test]
    fn clip_proxy_in_range() {
        let fx = FeatureExtractor::new(64, 32, 3);
        let mut rng = Pcg64::new(2);
        let a = rng.normal_vec(64);
        let c = rng.normal_vec(48);
        let v = clip_proxy(&fx, &a, &c);
        assert!((0.0..=33.0).contains(&v), "{v}");
    }
}
