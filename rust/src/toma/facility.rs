//! Greedy facility-location destination selection (Sec. 4.1, Alg. 2).
//!
//! Implements the cached-max formulation of App. A.1: the marginal gain of
//! candidate `i` against the selected set is `sum_j max(0, S_ij - m_j)`
//! where `m_j` caches token `j`'s best similarity to the current set.
//!
//! Since PR 1 the greedy loop maintains gains *incrementally* (lazy greedy
//! / CELF, Minoux 1978): every candidate keeps a cached gain from the last
//! round it was evaluated in. Submodularity makes that cache an upper
//! bound — selecting a destination only raises `m`, which only shrinks
//! `max(0, S_ij - m_j)` terms — so each round pops the largest cached
//! gain from a max-heap and re-scores only until a candidate's *fresh*
//! gain tops the heap. Per-round cost drops from the seed's full O(n²)
//! rescan to O(n · rescored), with rescored typically a handful.
//!
//! Since PR 5 the gain scan itself is lowered onto the microkernel seam
//! ([`tensor::kernel::relu_gain`](crate::tensor::kernel::relu_gain)): an
//! 8-lane rectified sum that the scalar and SIMD kernels compute
//! **bit-identically**, so selections never depend on `TOMA_KERNEL`.
//! Every gain in this module — cached, re-scored, and the reference's —
//! goes through the same single function with the same summation order,
//! and ties break toward the smaller index exactly like the seed's
//! strict-`>` ascending argmax — so the selected index set is identical
//! to [`fl_select_ref`], which the property tests assert.

use crate::tensor::kernel;
use crate::tensor::ops::l2_normalize_rows;
use crate::tensor::pool;

/// Cosine similarity matrix S (n x n) of row-major features x (n x d).
pub fn similarity_matrix(x: &[f32], n: usize, d: usize) -> Vec<f32> {
    assert_eq!(x.len(), n * d);
    let mut xn = x.to_vec();
    l2_normalize_rows(&mut xn, n, d);
    crate::tensor::ops::matmul_bt(&xn, &xn, n, d, n)
}

/// Marginal gain of one similarity row against the cached maxima `m`,
/// lowered onto the microkernel seam. One summation order everywhere
/// (greedy loop, heap rescore, and [`fl_select_ref`] all call this), and
/// the seam guarantees scalar and SIMD dispatches agree bitwise — so
/// cached and re-scored gains stay bit-identical and the CELF equivalence
/// property survives both the lowering and any `TOMA_KERNEL` setting.
#[inline]
fn gain_row(row: &[f32], m: &[f32]) -> f32 {
    kernel::relu_gain(row, m)
}

/// Max-heap entry: cached gain + the round it was computed in.
struct Entry {
    gain: f32,
    idx: usize,
    round: usize,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Larger gain wins; on exact ties the smaller index wins (matches
        // the reference's ascending strict-`>` argmax).
        self.gain
            .total_cmp(&other.gain)
            .then_with(|| other.idx.cmp(&self.idx))
    }
}

/// Greedy FL selection of `k` destinations from an (n x n) similarity
/// matrix. Returns sorted-ascending indices (matches `ref.fl_select`).
///
/// Same selection as [`fl_select_ref`], computed with incremental gain
/// maintenance instead of a full per-round rescan.
pub fn fl_select(sim: &[f32], n: usize, k: usize) -> Vec<usize> {
    assert_eq!(sim.len(), n * n);
    assert!(k >= 1 && k <= n);
    // m initialised to -1 (the cosine lower bound) so the first round
    // reduces to the row-sum rule of Alg. 2.
    let mut m = vec![-1.0f32; n];

    // Round-1 gains for every candidate, in parallel over row blocks
    // (serially for similarity matrices too small to amortize dispatch).
    let mut gains = vec![0.0f32; n];
    if n * n < pool::PAR_MIN_ELEMS {
        for (i, g) in gains.iter_mut().enumerate() {
            *g = gain_row(&sim[i * n..(i + 1) * n], &m);
        }
    } else {
        let m_ref = &m;
        let per = pool::rows_per_task(n);
        pool::parallel_chunks_mut(&mut gains, per, |ci, chunk| {
            for (off, g) in chunk.iter_mut().enumerate() {
                let i = ci * per + off;
                *g = gain_row(&sim[i * n..(i + 1) * n], m_ref);
            }
        });
    }

    let mut heap = std::collections::BinaryHeap::with_capacity(n + k);
    // `stamp[i]` is the round whose `m` the live heap entry for `i` was
    // scored against; entries with a stale stamp are superseded duplicates.
    let mut stamp = vec![1usize; n];
    for (i, &g) in gains.iter().enumerate() {
        heap.push(Entry {
            gain: g,
            idx: i,
            round: 1,
        });
    }

    let mut idx = Vec::with_capacity(k);
    for round in 1..=k {
        let t = loop {
            let e = heap.pop().expect("candidates remain");
            if stamp[e.idx] != e.round {
                continue; // superseded (or already selected)
            }
            if e.round == round {
                break e.idx; // fresh this round: the true argmax
            }
            // Stale upper bound: re-score against the current m and requeue.
            let g = gain_row(&sim[e.idx * n..(e.idx + 1) * n], &m);
            stamp[e.idx] = round;
            heap.push(Entry {
                gain: g,
                idx: e.idx,
                round,
            });
        };
        idx.push(t);
        stamp[t] = usize::MAX; // never pops again
        let row = &sim[t * n..(t + 1) * n];
        for (mm, s) in m.iter_mut().zip(row) {
            if *s > *mm {
                *mm = *s;
            }
        }
    }
    idx.sort_unstable();
    idx
}

/// The seed's full-rescan greedy selection — O(n²) per round. Retained as
/// the ground truth the incremental version must match index-for-index.
pub fn fl_select_ref(sim: &[f32], n: usize, k: usize) -> Vec<usize> {
    assert_eq!(sim.len(), n * n);
    assert!(k >= 1 && k <= n);
    let mut m = vec![-1.0f32; n];
    let mut avail = vec![true; n];
    let mut idx = Vec::with_capacity(k);
    for _ in 0..k {
        let mut best = usize::MAX;
        let mut best_gain = f32::NEG_INFINITY;
        for i in 0..n {
            if !avail[i] {
                continue;
            }
            let gain = gain_row(&sim[i * n..(i + 1) * n], &m);
            if gain > best_gain {
                best_gain = gain;
                best = i;
            }
        }
        let t = best;
        idx.push(t);
        avail[t] = false;
        let row = &sim[t * n..(t + 1) * n];
        for (mm, s) in m.iter_mut().zip(row) {
            if *s > *mm {
                *mm = *s;
            }
        }
    }
    idx.sort_unstable();
    idx
}

/// Facility-location objective f_FL(D) = sum_i max_{j in D} S_ij.
pub fn fl_objective(sim: &[f32], n: usize, idx: &[usize]) -> f32 {
    let mut total = 0.0f32;
    for i in 0..n {
        let row = &sim[i * n..(i + 1) * n];
        let mut best = f32::NEG_INFINITY;
        for &j in idx {
            best = best.max(row[j]);
        }
        total += best;
    }
    total
}

/// Per-region FL selection: features (regions, n_loc, d) flattened; returns
/// region-local destination indices (regions, k_loc) flattened. Regions are
/// independent, so they fan out across the worker pool (the per-region
/// similarity GEMM then runs serially on its worker).
pub fn fl_select_regions(
    xs: &[f32],
    regions: usize,
    n_loc: usize,
    d: usize,
    k_loc: usize,
) -> Vec<usize> {
    assert_eq!(xs.len(), regions * n_loc * d);
    let mut out = vec![0usize; regions * k_loc];
    if k_loc == 0 {
        return out;
    }
    let select_region = |p: usize, chunk: &mut [usize]| {
        let block = &xs[p * n_loc * d..(p + 1) * n_loc * d];
        let sim = similarity_matrix(block, n_loc, d);
        chunk.copy_from_slice(&fl_select(&sim, n_loc, k_loc));
    };
    // Region work is dominated by the n_loc^2 * d similarity GEMM; tiny
    // totals run serially rather than paying pool dispatch.
    if regions == 1 || regions * n_loc * n_loc * d < pool::PAR_MIN_ELEMS {
        for p in 0..regions {
            select_region(p, &mut out[p * k_loc..(p + 1) * k_loc]);
        }
    } else {
        pool::parallel_chunks_mut(&mut out, k_loc, |p, chunk| select_region(p, chunk));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Pcg64};

    fn randn(n: usize, d: usize, seed: u64) -> Vec<f32> {
        Pcg64::new(seed).normal_vec(n * d)
    }

    #[test]
    fn similarity_diag_one_symmetric() {
        let x = randn(10, 6, 0);
        let s = similarity_matrix(&x, 10, 6);
        for i in 0..10 {
            assert!((s[i * 10 + i] - 1.0).abs() < 1e-4);
            for j in 0..10 {
                assert!((s[i * 10 + j] - s[j * 10 + i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn select_sorted_unique_in_range() {
        let x = randn(24, 8, 1);
        let s = similarity_matrix(&x, 24, 8);
        let idx = fl_select(&s, 24, 10);
        assert_eq!(idx.len(), 10);
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
        assert!(idx.iter().all(|&i| i < 24));
    }

    #[test]
    fn objective_monotone_in_k() {
        let x = randn(20, 6, 2);
        let s = similarity_matrix(&x, 20, 6);
        let mut prev = f32::NEG_INFINITY;
        for k in [2, 4, 8, 16] {
            let v = fl_objective(&s, 20, &fl_select(&s, 20, k));
            assert!(v >= prev - 1e-4);
            prev = v;
        }
    }

    #[test]
    fn duplicates_covered_by_one() {
        // 4 copies of 4 base tokens: k=4 gives perfect coverage.
        let base = randn(4, 8, 3);
        let mut x = vec![];
        for _ in 0..4 {
            x.extend_from_slice(&base);
        }
        let s = similarity_matrix(&x, 16, 8);
        let idx = fl_select(&s, 16, 4);
        assert!(fl_objective(&s, 16, &idx) > 16.0 - 1e-2);
    }

    #[test]
    fn greedy_achieves_constant_factor() {
        // (1 - 1/e) guarantee vs brute force at k=2 on a tiny set.
        let x = randn(7, 4, 4);
        let s = similarity_matrix(&x, 7, 4);
        let got = fl_objective(&s, 7, &fl_select(&s, 7, 2));
        let mut best = f32::NEG_INFINITY;
        for i in 0..7 {
            for j in (i + 1)..7 {
                best = best.max(fl_objective(&s, 7, &[i, j]));
            }
        }
        assert!(got >= (1.0 - 1.0 / std::f32::consts::E) * best - 1e-4);
    }

    #[test]
    fn regions_independent() {
        let x = randn(32, 4, 5);
        let idx = fl_select_regions(&x, 4, 8, 4, 3);
        assert_eq!(idx.len(), 12);
        for chunk in idx.chunks(3) {
            assert!(chunk.windows(2).all(|w| w[0] < w[1]));
            assert!(chunk.iter().all(|&i| i < 8));
        }
    }

    #[test]
    fn incremental_matches_reference_on_duplicates() {
        // Duplicate tokens force exact gain ties: the tie-break must match
        // the reference's smallest-index rule.
        let base = randn(6, 5, 7);
        let mut x = vec![];
        for _ in 0..3 {
            x.extend_from_slice(&base);
        }
        let s = similarity_matrix(&x, 18, 5);
        for k in [1, 2, 5, 9, 18] {
            assert_eq!(fl_select(&s, 18, k), fl_select_ref(&s, 18, k), "k={k}");
        }
    }

    #[test]
    fn prop_incremental_bit_identical_to_reference() {
        prop::check("fl incremental == ref", 40, |g| {
            let n = g.usize_in(2, 48);
            let d = g.usize_in(2, 8);
            let k = g.usize_in(1, n);
            let x = if g.bool() {
                g.normal_vec(n * d)
            } else {
                // Clustered features: near-duplicate rows, tie-heavy gains.
                let protos = g.normal_vec(3 * d);
                let mut xs = Vec::with_capacity(n * d);
                for i in 0..n {
                    xs.extend_from_slice(&protos[(i % 3) * d..(i % 3 + 1) * d]);
                }
                xs
            };
            let sim = similarity_matrix(&x, n, d);
            prop::assert_prop(
                fl_select(&sim, n, k) == fl_select_ref(&sim, n, k),
                "incremental selection diverged from full-rescan reference",
            );
        });
    }

    #[test]
    fn prop_gain_cache_consistency() {
        // Property: after selection, every token's cached best similarity
        // equals its true max over the selected set.
        prop::check("fl cache", 24, |g| {
            let n = g.usize_in(4, 20);
            let d = g.usize_in(2, 8);
            let k = g.usize_in(1, n);
            let x = g.normal_vec(n * d);
            let sim = similarity_matrix(&x, n, d);
            let idx = fl_select(&sim, n, k);
            // Recompute objective two ways.
            let direct = fl_objective(&sim, n, &idx);
            let mut acc = 0.0f32;
            for i in 0..n {
                let mut best = f32::NEG_INFINITY;
                for &j in &idx {
                    best = best.max(sim[i * n + j]);
                }
                acc += best;
            }
            prop::assert_prop((direct - acc).abs() < 1e-3, "objective consistent");
            prop::assert_prop(
                idx.len() == k && idx.iter().all(|&i| i < n),
                "selection valid",
            );
        });
    }
}
