//! Merge plans and the reuse schedule (Sec. 4.3.2).
//!
//! A [`MergePlan`] bundles everything one denoising step needs to merge and
//! unmerge: the destination indices and the flattened `A~` weights for every
//! (batch x region) block. [`ReuseSchedule`] encodes the paper's
//! "destinations every 10 steps, weights every 5 steps" amortization; the
//! coordinator's plan cache consults it each step.

use super::regions::RegionLayout;

/// Destination indices + merge weights for one (model, ratio, layout) key.
#[derive(Clone, Debug)]
pub struct MergePlan {
    /// Region-local destination indices, (groups, d_loc) flattened, where
    /// groups = batch x regions.
    pub idx: Vec<i32>,
    /// Row-normalized merge weights A~, (groups, d_loc, n_loc) flattened.
    pub a_tilde: Vec<f32>,
    /// Column-softmax weights A (same shape) — needed only by the
    /// colsoftmax unmerge extension; empty otherwise.
    pub a: Vec<f32>,
    pub groups: usize,
    pub d_loc: usize,
    pub n_loc: usize,
    /// Step at which destinations were last selected.
    pub dest_step: u64,
    /// Step at which weights were last rebuilt.
    pub weight_step: u64,
}

impl MergePlan {
    pub fn merged_tokens_per_batch(&self, regions: usize) -> usize {
        regions * self.d_loc
    }

    /// Drop one cohort member's `regions` consecutive group blocks (the
    /// member completed and left the cohort); the remaining members'
    /// slices shift down but keep their relative order, so member index
    /// `i` in the cohort always owns groups `[i*regions, (i+1)*regions)`.
    pub fn remove_member(&mut self, member: usize, regions: usize) {
        let g0 = member * regions;
        assert!(g0 + regions <= self.groups, "member {member} out of range");
        let dl = self.d_loc;
        let nl = self.n_loc;
        self.idx.drain(g0 * dl..(g0 + regions) * dl);
        self.a_tilde.drain(g0 * dl * nl..(g0 + regions) * dl * nl);
        if !self.a.is_empty() {
            self.a.drain(g0 * dl * nl..(g0 + regions) * dl * nl);
        }
        self.groups -= regions;
    }

    /// Global token ids of the destinations for batch element `b`.
    pub fn global_destinations(&self, layout: &RegionLayout, b: usize) -> Vec<usize> {
        let regions = layout.regions;
        let mut out = Vec::with_capacity(regions * self.d_loc);
        for p in 0..regions {
            let g = b * regions + p;
            for s in 0..self.d_loc {
                let local = self.idx[g * self.d_loc + s] as usize;
                out.push(layout.token_at(p, local));
            }
        }
        out
    }
}

/// When to recompute destinations / weights (Sec. 4.3.2 + Table 8).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReuseSchedule {
    /// Re-run destination selection every `dest_every` steps.
    pub dest_every: u64,
    /// Rebuild merge weights every `weight_every` steps.
    pub weight_every: u64,
}

impl Default for ReuseSchedule {
    fn default() -> Self {
        // Paper default: destinations every 10, weights every 5.
        ReuseSchedule {
            dest_every: 10,
            weight_every: 5,
        }
    }
}

/// What the plan cache must do at a given step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanAction {
    /// Run destination selection AND rebuild weights.
    RefreshAll,
    /// Keep destinations, rebuild weights only.
    RefreshWeights,
    /// Reuse the cached plan untouched.
    Reuse,
    /// A [`RefreshAll`](PlanAction::RefreshAll) that was downgraded to a
    /// plan-cache install: the fingerprint of the refresh input matched a
    /// completed plan within the configured tolerance, so selection and
    /// weight building were skipped entirely. Never returned by
    /// [`ReuseSchedule::action`] — only the refresh sites produce it, after
    /// consulting `coordinator::plan_cache::PlanCache`.
    ReuseCached,
}

impl ReuseSchedule {
    pub fn every_step() -> Self {
        ReuseSchedule {
            dest_every: 1,
            weight_every: 1,
        }
    }

    /// Decide the action for `step` given the cached plan (if any).
    pub fn action(&self, step: u64, cached: Option<&MergePlan>) -> PlanAction {
        let plan = match cached {
            None => return PlanAction::RefreshAll,
            Some(p) => p,
        };
        if step >= plan.dest_step + self.dest_every {
            PlanAction::RefreshAll
        } else if step >= plan.weight_step + self.weight_every {
            PlanAction::RefreshWeights
        } else {
            PlanAction::Reuse
        }
    }

    /// Fraction of steps that run *any* recompute, for overhead accounting.
    pub fn recompute_fraction(&self) -> f64 {
        1.0 / self.weight_every as f64
    }

    /// True when `action(step, cached)` is [`PlanAction::RefreshAll`] —
    /// the only step at which a new cohort member may join batched
    /// serving and still observe, from its local step 0, exactly the
    /// refresh cadence a dedicated per-request engine would give it
    /// (every refresh window starts with a full refresh, so window
    /// offsets relative to the join step coincide).
    pub fn is_refresh_boundary(&self, step: u64, cached: Option<&MergePlan>) -> bool {
        self.action(step, cached) == PlanAction::RefreshAll
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::toma::regions::{RegionLayout, RegionMode};

    fn plan(dest_step: u64, weight_step: u64) -> MergePlan {
        MergePlan {
            idx: vec![0, 2],
            a_tilde: vec![],
            a: vec![],
            groups: 1,
            d_loc: 2,
            n_loc: 4,
            dest_step,
            weight_step,
        }
    }

    #[test]
    fn cold_cache_refreshes_all() {
        let s = ReuseSchedule::default();
        assert_eq!(s.action(0, None), PlanAction::RefreshAll);
    }

    #[test]
    fn paper_schedule_10_5() {
        let s = ReuseSchedule::default();
        let p = plan(0, 0);
        assert_eq!(s.action(1, Some(&p)), PlanAction::Reuse);
        assert_eq!(s.action(4, Some(&p)), PlanAction::Reuse);
        assert_eq!(s.action(5, Some(&p)), PlanAction::RefreshWeights);
        let p2 = plan(0, 5);
        assert_eq!(s.action(9, Some(&p2)), PlanAction::Reuse);
        assert_eq!(s.action(10, Some(&p2)), PlanAction::RefreshAll);
    }

    #[test]
    fn every_step_always_refreshes() {
        let s = ReuseSchedule::every_step();
        let p = plan(3, 3);
        assert_eq!(s.action(4, Some(&p)), PlanAction::RefreshAll);
    }

    #[test]
    fn global_destinations_map_through_layout() {
        let layout = RegionLayout::new(RegionMode::Stripe, 2, 2, 4);
        // 8 tokens, 2 stripes of 4; batch 1, d_loc 2, idx picks slots 1,3
        // in region 0 and 0,2 in region 1.
        let p = MergePlan {
            idx: vec![1, 3, 0, 2],
            a_tilde: vec![],
            a: vec![],
            groups: 2,
            d_loc: 2,
            n_loc: 4,
            dest_step: 0,
            weight_step: 0,
        };
        assert_eq!(p.global_destinations(&layout, 0), vec![1, 3, 4, 6]);
        assert_eq!(p.merged_tokens_per_batch(2), 4);
    }

    #[test]
    fn recompute_fraction() {
        assert!((ReuseSchedule::default().recompute_fraction() - 0.2).abs() < 1e-9);
        assert!((ReuseSchedule::every_step().recompute_fraction() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn refresh_boundaries_mark_join_steps() {
        let s = ReuseSchedule::default();
        // Cold cache: always a boundary.
        assert!(s.is_refresh_boundary(3, None));
        let p = plan(0, 5);
        assert!(!s.is_refresh_boundary(7, Some(&p)), "mid-window");
        assert!(s.is_refresh_boundary(10, Some(&p)), "dest refresh due");
        // every_step: every step is a boundary (continuous joining).
        assert!(ReuseSchedule::every_step().is_refresh_boundary(4, Some(&plan(3, 3))));
    }

    #[test]
    fn remove_member_drops_exactly_one_block() {
        // 3 members x 2 regions, d_loc 2, n_loc 3.
        let (members, regions, dl, nl) = (3usize, 2usize, 2usize, 3usize);
        let groups = members * regions;
        let idx: Vec<i32> = (0..groups * dl).map(|v| v as i32).collect();
        let a_tilde: Vec<f32> = (0..groups * dl * nl).map(|v| v as f32).collect();
        let mut p = MergePlan {
            idx: idx.clone(),
            a_tilde: a_tilde.clone(),
            a: vec![],
            groups,
            d_loc: dl,
            n_loc: nl,
            dest_step: 4,
            weight_step: 9,
        };
        p.remove_member(1, regions);
        assert_eq!(p.groups, (members - 1) * regions);
        // Member 0's block unchanged, member 2's block shifted down.
        assert_eq!(&p.idx[..regions * dl], &idx[..regions * dl]);
        assert_eq!(&p.idx[regions * dl..], &idx[2 * regions * dl..]);
        assert_eq!(&p.a_tilde[..regions * dl * nl], &a_tilde[..regions * dl * nl]);
        assert_eq!(&p.a_tilde[regions * dl * nl..], &a_tilde[2 * regions * dl * nl..]);
        // Cadence bookkeeping is untouched by membership changes.
        assert_eq!(p.dest_step, 4);
        assert_eq!(p.weight_step, 9);
    }
}
