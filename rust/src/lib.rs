//! # ToMA — Token Merge with Attention for Diffusion Models
//!
//! Full-system reproduction of *ToMA: Token Merge with Attention for
//! Diffusion Models* (ICML 2025) as a three-layer Rust + JAX + Pallas stack.
//!
//! This crate is **Layer 3**: the serving coordinator that owns the
//! denoising loop, dynamic request batching, and — the heart of the paper's
//! Sec. 4.3 — the *merge-plan cache* that decides when destination tokens
//! and merge weights are recomputed versus reused. Model compute runs
//! through AOT-compiled XLA artifacts (see `runtime`); Python never executes
//! at serve time.
//!
//! Module map (see DESIGN.md for the experiment index):
//!
//! * [`toma`] — host reference of the paper's operators: facility-location
//!   selection, attention merge, transpose/pinv unmerge, region layouts.
//! * [`baselines`] — ToMeSD / ToFu / ToDo / TLB reimplementations.
//! * [`coordinator`] — engine, batcher, plan cache, server, metrics.
//! * [`runtime`] — PJRT client, artifact registry, weight store.
//! * [`diffusion`] — DDIM / Euler samplers and noise schedules.
//! * [`model`] — pure-Rust UVitLite forward (cross-validation substrate).
//! * [`gpucost`] — per-GPU roofline model regenerating the paper's latency
//!   tables on hardware we do not have.
//! * [`quality`] — DINO/CLIP/FID proxy metrics.
//! * [`tensor`], [`util`], [`workload`], [`report`], [`bench`] — substrates.

pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod diffusion;
pub mod gpucost;
pub mod model;
pub mod quality;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod toma;
pub mod util;
pub mod workload;

/// Repo-relative default artifact directory (`make artifacts` output).
pub fn default_artifact_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("TOMA_ARTIFACTS") {
        return dir.into();
    }
    let mut p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    p.push("artifacts");
    p
}
