//! Pure-Rust model forward passes.
//!
//! These mirror the JAX definitions in `python/compile/model.py` layer for
//! layer and read the same weight npz. They serve two roles:
//! 1. **Cross-validation**: integration tests check the PJRT artifacts
//!    against this independent implementation (same inputs, same weights,
//!    numerics within f32 accumulation tolerance).
//! 2. **Host baseline substrate**: lets the ToMe/ToFu/ToDo comparisons and
//!    the Table 6 micro-benchmarks run without the XLA runtime.

pub mod uvit;

pub use uvit::{BatchReduce, BatchSample, HostReduce, HostUVit, Linear, UVitParams};
