//! Roofline time estimation: each op costs
//! `max(flops / eff_flops, bytes / eff_bw) + launch overhead`,
//! with efficiency chosen by op class.

use super::device::Gpu;
use super::ops::Op;

/// Estimated wall time of a single op on a device, in seconds.
pub fn op_time(gpu: &Gpu, op: &Op) -> f64 {
    let flops = op.flops();
    let bytes = op.bytes();
    let (flop_eff, bw_eff) = match op {
        Op::Gemm { .. } => (gpu.gemm_eff, gpu.stream_eff),
        Op::Attention { .. } => (gpu.attn_eff, gpu.stream_eff),
        Op::Sort { .. } => (1.0, gpu.stream_eff),
        _ if op.scattered() => (1.0, gpu.scatter_eff),
        _ => (1.0, gpu.stream_eff),
    };
    let t_flop = match op {
        Op::Sort { n } => *n as f64 / (gpu.sort_rate * gpu.speed),
        _ => flops / gpu.effective_flops(flop_eff).max(1.0),
    };
    let t_mem = bytes / gpu.effective_bw(bw_eff).max(1.0);
    t_flop.max(t_mem) + op.launches() as f64 * gpu.launch_s
}

/// Total estimated time of an op sequence, seconds.
pub fn estimate_time(gpu: &Gpu, ops: &[Op]) -> f64 {
    ops.iter().map(|op| op_time(gpu, op)).sum()
}

/// Breakdown by coarse category (for the §Perf analysis and Table 10).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TimeBreakdown {
    pub gemm: f64,
    pub attention: f64,
    pub scattered: f64,
    pub sort: f64,
    pub other: f64,
    pub launch: f64,
}

impl TimeBreakdown {
    pub fn total(&self) -> f64 {
        self.gemm + self.attention + self.scattered + self.sort + self.other + self.launch
    }
}

pub fn breakdown(gpu: &Gpu, ops: &[Op]) -> TimeBreakdown {
    let mut b = TimeBreakdown::default();
    for op in ops {
        let launch = op.launches() as f64 * gpu.launch_s;
        let t = op_time(gpu, op) - launch;
        b.launch += launch;
        match op {
            Op::Gemm { .. } => b.gemm += t,
            Op::Attention { .. } => b.attention += t,
            Op::Sort { .. } => b.sort += t,
            _ if op.scattered() => b.scattered += t,
            _ => b.other += t,
        }
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpucost::device::GpuModel;

    fn gpu() -> Gpu {
        Gpu::profile(GpuModel::Rtx6000)
    }

    #[test]
    fn bigger_gemm_takes_longer() {
        let g = gpu();
        let small = op_time(&g, &Op::Gemm { m: 128, k: 128, n: 128 });
        let large = op_time(&g, &Op::Gemm { m: 1024, k: 1024, n: 1024 });
        assert!(large > small);
    }

    #[test]
    fn gather_slower_than_copy_same_bytes() {
        let g = gpu();
        // Same data volume, scattered vs streaming.
        let gather = op_time(&g, &Op::Gather { rows: 4096, d: 640 });
        let copy = op_time(&g, &Op::Copy { n: 4096 * 640 });
        assert!(gather > 2.0 * copy, "{gather} vs {copy}");
    }

    #[test]
    fn launch_floor_for_tiny_ops() {
        let g = gpu();
        let t = op_time(&g, &Op::Gemm { m: 1, k: 1, n: 1 });
        assert!(t >= g.launch_s);
    }

    #[test]
    fn breakdown_sums_to_estimate() {
        let g = gpu();
        let ops = vec![
            Op::Gemm { m: 512, k: 512, n: 512 },
            Op::Attention { q: 1024, kv: 1024, d: 640 },
            Op::Sort { n: 3072 },
            Op::Gather { rows: 1024, d: 640 },
            Op::Copy { n: 65536 },
        ];
        let b = breakdown(&g, &ops);
        let t = estimate_time(&g, &ops);
        assert!((b.total() - t).abs() < 1e-9 * t.max(1.0));
        assert!(b.sort > 0.0 && b.scattered > 0.0 && b.gemm > 0.0);
    }

    #[test]
    fn table6_shape_gemm_merge_beats_gather_merge() {
        // The micro-benchmark claim (Table 6): at N=1024, d=640, the dense
        // GEMM merge is ~4-5x faster than index gather + scatter merge.
        let g = gpu();
        let n = 1024;
        let d = 640;
        let k = 512;
        let toma = estimate_time(&g, &[Op::Gemm { m: k, k: n, n: d }]);
        let tome = estimate_time(
            &g,
            &[
                Op::Gather { rows: n - k, d },
                Op::ScatterAdd { rows: n - k, d },
                Op::Launches { count: 4 }, // index bookkeeping dispatches
            ],
        );
        let speedup = tome / toma;
        assert!(
            (2.0..12.0).contains(&speedup),
            "speedup {speedup} out of plausible range"
        );
    }
}
