//! Reimplementations of the heuristic token-reduction baselines the paper
//! compares against (Table 3, Table 6), including their GPU-unfriendly
//! primitives (argsort, gather, scatter-add) so the overhead comparison
//! with ToMA's dense-GEMM merge is honest.

pub mod tlb;
pub mod todo;
pub mod tome;

pub use tlb::TlbReducer;
pub use todo::todo_pool;
pub use tome::{TomeMode, TomePlan};
