//! Step-level continuous micro-batching — plan-compatible batched serving.
//!
//! The per-request `Server` runs one engine per request; this subsystem
//! instead admits requests into *cohorts* keyed by plan-compatibility
//! (`EngineConfig::key()`: same model, variant, ratio, select mode and
//! reuse schedule ⇒ same per-step [`PlanAction`] sequence) and advances a
//! cohort through the backend **one batched denoising step at a time**:
//!
//! * one [`PlanSlot`](crate::coordinator::PlanSlot) per cohort —
//!   selection / weights rebuilds are
//!   decided and counted once per cohort step, not once per request
//!   (Sec. 4.3.2's amortization made batch-level);
//! * requests join mid-flight at `RefreshAll` boundaries and leave on
//!   completion, so lanes stay full under continuous arrivals;
//! * the model step itself is the batch-folded
//!   [`HostUVit::forward_batch`](crate::model::HostUVit::forward_batch),
//!   which is bitwise fold-invariant — batched latents equal per-request
//!   latents for the same seeds (see `tests/scheduler_equivalence.rs`).
//!
//! [`BatchPolicy`] bounds the cohort size, the formation window, the lane
//! queue depth (backpressure: `try_submit` fails fast) and admission
//! deadlines (overdue requests are shed, not served late).

pub mod cohort;
pub mod host;
pub mod policy;

pub use cohort::{Cohort, CohortBackend, CohortCompletion, MemberState, StepOutcome};
pub use host::{HostBackend, HostContext, HostEngine, DEFAULT_TAU};
pub use policy::BatchPolicy;

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError,
    TrySendError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::anyhow;
use crate::toma::plan::PlanAction;
use crate::util::error::Result;

use super::metrics::Metrics;
use super::plan_cache::PlanStats;
use super::request::{EngineConfig, GenRequest, GenResult};
use super::server::Completion;

/// Creates the batched backend for a new lane (one lane per engine key).
pub type BackendFactory =
    dyn Fn(&EngineConfig) -> Result<Box<dyn CohortBackend>> + Send + Sync;

struct SchedJob {
    request: GenRequest,
    enqueued: Instant,
    done: Sender<Completion>,
}

struct SchedLane {
    tx: SyncSender<SchedJob>,
    handle: JoinHandle<()>,
    /// Identity of this lane incarnation (see [`Scheduler::evict_lane`]).
    generation: u64,
}

/// The micro-batching front-end: submit requests, get completions.
pub struct Scheduler {
    policy: BatchPolicy,
    pub metrics: Arc<Metrics>,
    factory: Arc<BackendFactory>,
    lanes: Mutex<BTreeMap<String, SchedLane>>,
    next_generation: std::sync::atomic::AtomicU64,
}

impl Scheduler {
    pub fn new<F>(policy: BatchPolicy, factory: F) -> Scheduler
    where
        F: Fn(&EngineConfig) -> Result<Box<dyn CohortBackend>> + Send + Sync + 'static,
    {
        Scheduler {
            policy: policy.normalized(),
            metrics: Arc::new(Metrics::new()),
            factory: Arc::new(factory),
            lanes: Mutex::new(BTreeMap::new()),
            next_generation: std::sync::atomic::AtomicU64::new(1),
        }
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// The lane's sender plus the generation it belongs to — the identity
    /// a failed submit must present to [`Scheduler::evict_lane`].
    fn lane_tx(&self, cfg: &EngineConfig) -> (SyncSender<SchedJob>, u64) {
        let mut lanes = self.lanes.lock().unwrap();
        let lane = lanes
            .entry(cfg.key())
            .or_insert_with(|| self.spawn_lane(cfg));
        (lane.tx.clone(), lane.generation)
    }

    /// Remove the lane for `key` only if it is still the `generation` the
    /// caller observed failing. A submitter racing a respawn would
    /// otherwise evict the *fresh, healthy* lane another submitter just
    /// spawned (the ROADMAP dead-lane race) — generation mismatch makes
    /// the stale eviction a no-op. Returns whether a lane was evicted.
    fn evict_lane(&self, key: &str, generation: u64) -> bool {
        let mut lanes = self.lanes.lock().unwrap();
        if lanes.get(key).map(|l| l.generation) == Some(generation) {
            lanes.remove(key);
            true
        } else {
            false
        }
    }

    fn spawn_lane(&self, cfg: &EngineConfig) -> SchedLane {
        let (tx, rx) = sync_channel::<SchedJob>(self.policy.queue_depth);
        let policy = self.policy;
        let metrics = self.metrics.clone();
        let factory = self.factory.clone();
        let cfg = cfg.clone();
        let handle = std::thread::Builder::new()
            .name("toma-sched".to_string())
            .spawn(move || lane_loop(&cfg, policy, &factory, &metrics, rx))
            .expect("spawn scheduler lane");
        let generation = self
            .next_generation
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        SchedLane {
            tx,
            handle,
            generation,
        }
    }

    /// Submit a request; blocks when the lane queue is full
    /// (backpressure). The completion arrives on the returned channel.
    /// A dead lane (e.g. a panicked backend) fails the request with an
    /// error completion and is respawned on the next submit — one bad
    /// request must not poison the serving process.
    pub fn submit(&self, cfg: &EngineConfig, request: GenRequest) -> Receiver<Completion> {
        let (tx, generation) = self.lane_tx(cfg);
        let (done_tx, done_rx) = channel();
        self.metrics.inc("requests_submitted");
        let job = SchedJob {
            request,
            enqueued: Instant::now(),
            done: done_tx,
        };
        if let Err(std::sync::mpsc::SendError(job)) = tx.send(job) {
            self.metrics.inc("requests_err");
            self.evict_lane(&cfg.key(), generation);
            let _ = job.done.send(Completion {
                request: job.request,
                result: Err(anyhow!("scheduler lane died; resubmit")),
                queued_s: 0.0,
                service_s: 0.0,
            });
        }
        done_rx
    }

    /// Non-blocking submit: fails fast when the lane queue is at its
    /// `BatchPolicy::queue_depth` bound.
    pub fn try_submit(
        &self,
        cfg: &EngineConfig,
        request: GenRequest,
    ) -> Result<Receiver<Completion>> {
        let (tx, generation) = self.lane_tx(cfg);
        let (done_tx, done_rx) = channel();
        match tx.try_send(SchedJob {
            request,
            enqueued: Instant::now(),
            done: done_tx,
        }) {
            Ok(()) => {
                self.metrics.inc("requests_submitted");
                Ok(done_rx)
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.inc("requests_rejected");
                Err(anyhow!(
                    "lane queue full ({} deep): backpressure",
                    self.policy.queue_depth
                ))
            }
            Err(TrySendError::Disconnected(_)) => {
                // Dead lane: drop *this incarnation* so the next submit
                // respawns fresh (never a healthy respawn that beat us).
                self.evict_lane(&cfg.key(), generation);
                Err(anyhow!("scheduler lane died; resubmit"))
            }
        }
    }

    /// Run a batch to completion (closed loop), preserving submission
    /// order in the result. A lane dying mid-request yields an error
    /// completion for the affected requests rather than a panic.
    pub fn run_batch(&self, cfg: &EngineConfig, requests: Vec<GenRequest>) -> Vec<Completion> {
        let pairs: Vec<(GenRequest, Receiver<Completion>)> = requests
            .into_iter()
            .map(|r| {
                let rx = self.submit(cfg, r.clone());
                (r, rx)
            })
            .collect();
        pairs
            .into_iter()
            .map(|(request, rx)| {
                rx.recv().unwrap_or_else(|_| Completion {
                    request,
                    result: Err(anyhow!("scheduler lane died mid-request")),
                    queued_s: 0.0,
                    service_s: 0.0,
                })
            })
            .collect()
    }

    /// Convenience: run a batch and return the successful results.
    pub fn run_batch_ok(
        &self,
        cfg: &EngineConfig,
        requests: Vec<GenRequest>,
    ) -> Result<Vec<GenResult>> {
        self.run_batch(cfg, requests)
            .into_iter()
            .map(|c| c.result)
            .collect()
    }

    /// Drop all lanes, joining scheduler threads.
    pub fn shutdown(&self) {
        let drained: Vec<SchedLane> =
            std::mem::take(&mut *self.lanes.lock().unwrap()).into_values().collect();
        for lane in drained {
            drop(lane.tx);
            let _ = lane.handle.join();
        }
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

struct JobMeta {
    request: GenRequest,
    done: Sender<Completion>,
    queued_s: f64,
    admitted: Instant,
}

/// The instant by which `job` must be admitted (submission time plus its
/// effective deadline), if it has one.
fn admission_deadline(policy: &BatchPolicy, job: &SchedJob) -> Option<Instant> {
    let dl = policy.deadline_for(job.request.deadline_s)?;
    let d = Duration::try_from_secs_f64(dl.max(0.0)).ok()?;
    job.enqueued.checked_add(d)
}

fn fail(metrics: &Metrics, meta: JobMeta, msg: &str) {
    metrics.inc("requests_err");
    let service_s = meta.admitted.elapsed().as_secs_f64();
    let _ = meta.done.send(Completion {
        request: meta.request,
        result: Err(anyhow!("{msg}")),
        queued_s: meta.queued_s,
        service_s,
    });
}

/// One lane: a bounded queue drained by a single cohort that steps
/// continuously. The loop blocks only while completely idle.
fn lane_loop(
    cfg: &EngineConfig,
    policy: BatchPolicy,
    factory: &BackendFactory,
    metrics: &Metrics,
    rx: Receiver<SchedJob>,
) {
    let backend = match factory(cfg) {
        Ok(b) => b,
        Err(e) => {
            // Fail every job this lane would serve.
            let msg = format!("backend init failed: {e}");
            while let Ok(job) = rx.recv() {
                metrics.inc("requests_err");
                let _ = job.done.send(Completion {
                    request: job.request,
                    result: Err(anyhow!("{msg}")),
                    queued_s: job.enqueued.elapsed().as_secs_f64(),
                    service_s: 0.0,
                });
            }
            return;
        }
    };
    let tokens_per_member = backend.tokens_per_member_step();
    let mut cohort = Cohort::new(backend);
    let mut pending: VecDeque<SchedJob> = VecDeque::new();
    let mut inflight: BTreeMap<u64, JobMeta> = BTreeMap::new();
    let mut open = true;

    loop {
        if cohort.is_empty() && pending.is_empty() {
            if !open {
                break;
            }
            // Idle: block for the first request of a new cohort, then hold
            // the formation window open for companions — clamped so no
            // pending request is held past its admission deadline just to
            // wait for company.
            match rx.recv() {
                Ok(j) => pending.push_back(j),
                Err(_) => break,
            }
            let window = Duration::from_secs_f64(policy.max_queue_wait_s);
            let mut wait_until = Instant::now() + window;
            if let Some(dl) = pending.back().and_then(|j| admission_deadline(&policy, j)) {
                wait_until = wait_until.min(dl);
            }
            while pending.len() < policy.max_batch {
                let remaining = wait_until.saturating_duration_since(Instant::now());
                if remaining.is_zero() {
                    break;
                }
                match rx.recv_timeout(remaining) {
                    Ok(j) => {
                        if let Some(dl) = admission_deadline(&policy, &j) {
                            wait_until = wait_until.min(dl);
                        }
                        pending.push_back(j);
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
        } else if open {
            // Mid-flight: drain the channel into `pending` (bounded by
            // queue_depth) so the deadline shed below sees every waiting
            // request each step, even while the cohort is full; admission
            // still gates joins on boundaries and max_batch. Effective
            // buffering is therefore up to queue_depth in `pending` plus
            // queue_depth in the channel.
            while pending.len() < policy.queue_depth {
                match rx.try_recv() {
                    Ok(j) => pending.push_back(j),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
        }

        // Deadline-aware draining: shed overdue requests *every* loop
        // iteration, not just at join boundaries — a dead request must be
        // rejected promptly, not after waiting out a reuse window.
        let mut kept = VecDeque::with_capacity(pending.len());
        for job in pending.drain(..) {
            let queued_s = job.enqueued.elapsed().as_secs_f64();
            match policy.deadline_for(job.request.deadline_s) {
                Some(dl) if queued_s > dl => {
                    metrics.inc("requests_shed");
                    let _ = job.done.send(Completion {
                        request: job.request,
                        result: Err(anyhow!(
                            "deadline exceeded in queue ({queued_s:.3}s > {dl:.3}s)"
                        )),
                        queued_s,
                        service_s: 0.0,
                    });
                }
                _ => kept.push_back(job),
            }
        }
        pending = kept;

        // Admit at join boundaries.
        while cohort.len() < policy.max_batch && !pending.is_empty() && cohort.can_join() {
            let job = pending.pop_front().expect("non-empty");
            let queued_s = job.enqueued.elapsed().as_secs_f64();
            metrics.observe_s("queue_wait", queued_s);
            // A join into a cohort that already stepped is a mid-flight
            // join; formation-batch admits (cohort_step 0) are not.
            let mid_flight = cohort.cohort_step() > 0 && !cohort.is_empty();
            match cohort.admit(&job.request) {
                Ok(tag) => {
                    if mid_flight {
                        metrics.inc("cohort_joins");
                    }
                    inflight.insert(
                        tag,
                        JobMeta {
                            request: job.request,
                            done: job.done,
                            queued_s,
                            admitted: Instant::now(),
                        },
                    );
                }
                Err(e) => {
                    metrics.inc("requests_err");
                    let _ = job.done.send(Completion {
                        request: job.request,
                        result: Err(e),
                        queued_s,
                        service_s: 0.0,
                    });
                }
            }
        }

        if cohort.is_empty() {
            if !open && pending.is_empty() {
                break;
            }
            continue;
        }

        // One batched step for the whole cohort.
        let t0 = Instant::now();
        match cohort.step() {
            Ok(out) => {
                metrics.inc("cohort_steps");
                metrics.add("cohort_member_steps", out.active_members as u64);
                metrics.add(
                    "tokens_denoised",
                    (out.active_members * tokens_per_member) as u64,
                );
                if let Some(a) = out.action {
                    let mut delta = PlanStats::default();
                    match a {
                        PlanAction::RefreshAll => delta.refresh_all = 1,
                        PlanAction::RefreshWeights => delta.refresh_weights = 1,
                        PlanAction::Reuse => delta.reuses = 1,
                    }
                    metrics.record_plan_stats("cohort", &delta);
                }
                metrics.observe_s("cohort_step_time", t0.elapsed().as_secs_f64());
                for mut c in out.completions {
                    let Some(meta) = inflight.remove(&c.tag) else {
                        continue;
                    };
                    let service_s = meta.admitted.elapsed().as_secs_f64();
                    // Batched steps are shared work, so per-phase timings
                    // (step_s/select_s) live in the lane histograms; the
                    // per-request wall time is attributable, so fill it.
                    if let Ok(r) = c.result.as_mut() {
                        r.stats.total_s = service_s;
                    }
                    metrics.observe_s("service_time", service_s);
                    metrics.observe_s("e2e_time", meta.queued_s + service_s);
                    metrics.inc(if c.result.is_ok() {
                        "requests_ok"
                    } else {
                        "requests_err"
                    });
                    let _ = meta.done.send(Completion {
                        request: c.request,
                        result: c.result,
                        queued_s: meta.queued_s,
                        service_s,
                    });
                }
            }
            Err(e) => {
                // A deterministic backend should never fail mid-step; if it
                // does, fail the whole cohort rather than wedging the lane.
                let msg = format!("cohort step failed: {e}");
                for (tag, _req) in cohort.drain() {
                    if let Some(meta) = inflight.remove(&tag) {
                        fail(metrics, meta, &msg);
                    }
                }
            }
        }
    }

    // Lane closing: anything still pending was never admitted.
    for job in pending {
        metrics.inc("requests_err");
        let _ = job.done.send(Completion {
            request: job.request,
            result: Err(anyhow!("scheduler lane shut down before admission")),
            queued_s: job.enqueued.elapsed().as_secs_f64(),
            service_s: 0.0,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::GenStats;
    use crate::model::HostUVit;
    use crate::runtime::ModelInfo;

    fn tiny_model() -> Arc<HostUVit> {
        let info = ModelInfo::synthetic("uvit_sched", 4, 2, 16, 2, 3, 5);
        Arc::new(HostUVit::synthetic(&info, 1, 99))
    }

    fn toma_cfg(steps: usize) -> EngineConfig {
        let mut cfg = EngineConfig::new("uvit_sched", "toma", Some(0.5));
        cfg.steps = steps;
        cfg
    }

    fn host_scheduler(policy: BatchPolicy) -> Scheduler {
        let model = tiny_model();
        Scheduler::new(policy, move |cfg: &EngineConfig| {
            HostBackend::boxed(model.clone(), cfg.clone(), 4, DEFAULT_TAU)
        })
    }

    #[test]
    fn closed_loop_batch_completes_all() {
        // Generous formation window so the closed-loop batch reliably
        // cohorts up even on a loaded CI machine.
        let s = host_scheduler(BatchPolicy {
            max_batch: 4,
            max_queue_wait_s: 0.25,
            ..Default::default()
        });
        let reqs: Vec<GenRequest> = (0..5).map(|i| GenRequest::new("cat", i)).collect();
        let comps = s.run_batch(&toma_cfg(6), reqs);
        assert_eq!(comps.len(), 5);
        for c in &comps {
            let r = c.result.as_ref().expect("ok");
            assert_eq!(r.stats.steps, 6);
            assert!(r.stats.cohort_size >= 1);
            assert!(r.latent.iter().all(|v| v.is_finite()));
        }
        assert_eq!(s.metrics.counter("requests_ok"), 5);
        // Amortization: fewer cohort refreshes than request-level ones
        // (5 requests would need 5 RefreshAll at batch size 1).
        assert!(s.metrics.counter("cohort_refresh_all") < 5);
        assert!(s.metrics.counter("tokens_denoised") > 0);
        s.shutdown();
    }

    #[test]
    fn deadline_zero_sheds_requests() {
        let s = host_scheduler(BatchPolicy::with_max_batch(2));
        let req = GenRequest::new("late", 1).with_deadline(0.0);
        let rx = s.submit(&toma_cfg(4), req);
        let c = rx.recv().expect("completion");
        let err = c.result.err().expect("shed").to_string();
        assert!(err.contains("deadline"), "unexpected error: {err}");
        assert_eq!(s.metrics.counter("requests_shed"), 1);
        s.shutdown();
    }

    #[test]
    fn try_submit_rejects_when_lane_queue_full() {
        // Hold the lane's backend factory on a condvar so the lane never
        // drains its queue; with queue_depth 1, the first submit fills
        // the channel and the second must fail fast with backpressure.
        let gate = Arc::new((Mutex::new(false), std::sync::Condvar::new()));
        let g2 = gate.clone();
        let s = Scheduler::new(
            BatchPolicy {
                queue_depth: 1,
                ..Default::default()
            },
            move |_cfg: &EngineConfig| {
                let (lock, cv) = &*g2;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
                Err(anyhow!("factory released"))
            },
        );
        let cfg = toma_cfg(2);
        let rx1 = s.submit(&cfg, GenRequest::new("a", 1));
        let err = s
            .try_submit(&cfg, GenRequest::new("b", 2))
            .err()
            .expect("second submit must hit backpressure");
        assert!(err.to_string().contains("backpressure"), "{err}");
        assert_eq!(s.metrics.counter("requests_rejected"), 1);
        // Release the lane; the queued request fails with the factory
        // error instead of hanging.
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        let c = rx1.recv().expect("completion");
        assert!(c.result.is_err());
        s.shutdown();
    }

    #[test]
    fn forced_lane_death_then_resubmit_respawns_generation_checked() {
        // First factory call panics, killing the lane thread mid-flight;
        // subsequent calls build a healthy host backend. This exercises
        // the full death -> stale-sender-detect -> evict -> respawn path.
        let model = tiny_model();
        let died = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let d2 = died.clone();
        let s = Scheduler::new(
            BatchPolicy {
                max_batch: 2,
                max_queue_wait_s: 0.01,
                ..Default::default()
            },
            move |cfg: &EngineConfig| {
                if !d2.swap(true, std::sync::atomic::Ordering::SeqCst) {
                    panic!("injected lane death");
                }
                HostBackend::boxed(model.clone(), cfg.clone(), 4, DEFAULT_TAU)
            },
        );
        let cfg = toma_cfg(3);
        // Depending on timing the dying lane either drops the completion
        // sender (recv errors) or the submit itself observes the dead
        // channel (error completion). Either way, resubmitting must reach
        // a healthy respawned lane within a few attempts.
        let mut served = false;
        for attempt in 0..4u64 {
            let rx = s.submit(&cfg, GenRequest::new("retry", attempt));
            if let Ok(c) = rx.recv() {
                if c.result.is_ok() {
                    served = true;
                    break;
                }
            }
        }
        assert!(served, "resubmit after forced lane death must be served");
        assert!(died.load(std::sync::atomic::Ordering::SeqCst));
        // The healthy lane is a fresh incarnation; the dead lane's
        // generation is permanently stale and cannot evict it.
        let (_tx, fresh) = s.lane_tx(&cfg);
        assert!(fresh > 1, "respawn must advance the generation");
        assert!(!s.evict_lane(&cfg.key(), fresh - 1));
        assert!(
            s.lanes.lock().unwrap().contains_key(&cfg.key()),
            "stale eviction must not remove the healthy lane"
        );
        // The current generation is the only one that may evict.
        assert!(s.evict_lane(&cfg.key(), fresh));
        s.shutdown();
    }

    #[test]
    fn backend_init_failure_fails_requests() {
        let s = Scheduler::new(BatchPolicy::default(), |_cfg: &EngineConfig| {
            Err(anyhow!("no such model"))
        });
        let rx = s.submit(&toma_cfg(2), GenRequest::new("x", 0));
        let c = rx.recv().expect("completion");
        let err = c.result.err().expect("must fail").to_string();
        assert!(err.contains("backend init failed"), "{err}");
        s.shutdown();
    }

    #[test]
    fn baseline_variant_runs_without_plans() {
        let s = host_scheduler(BatchPolicy::with_max_batch(2));
        let mut cfg = EngineConfig::new("uvit_sched", "baseline", None);
        cfg.steps = 3;
        let results = s
            .run_batch_ok(&cfg, vec![GenRequest::new("a", 1), GenRequest::new("b", 2)])
            .expect("ok");
        assert_eq!(results.len(), 2);
        assert_eq!(s.metrics.counter("cohort_refresh_all"), 0);
        let zero = GenStats::default();
        assert_eq!(results[0].stats.select_calls, zero.select_calls);
        s.shutdown();
    }
}
