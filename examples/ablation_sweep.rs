//! Ratio x variant ablation sweep on the real engine.
//!
//! Reproduces the quality-vs-efficiency trade-off structure of Table 1 on
//! the stand-in model: every ToMA variant at r in {0.25, 0.5, 0.75},
//! scored with the proxy metrics against the baseline output of the same
//! seeds, plus measured CPU step time.
//!
//! ```bash
//! cargo run --release --example ablation_sweep -- --steps 10 --prompts 3
//! ```

use std::sync::Arc;

use toma::util::error::Result;
use toma::coordinator::{Engine, EngineConfig, GenRequest};
use toma::quality::{dino_proxy, mse, FeatureExtractor};
use toma::report::Table;
use toma::runtime::Runtime;
use toma::util::argparse::Args;
use toma::workload::PromptSet;

fn main() -> Result<()> {
    let args = Args::from_env();
    let model = args.get_str("model", "uvit_xs");
    let steps = args.get_usize("steps", 10);
    let n_prompts = args.get_usize("prompts", 3);
    let runtime = Arc::new(Runtime::with_default_dir()?);
    let prompts = PromptSet::gemrec();

    let run = |cfg: &EngineConfig| -> Result<(Vec<Vec<f32>>, f64)> {
        let engine = Engine::new(runtime.clone(), cfg.clone())?;
        let mut outs = vec![];
        let mut secs = 0.0;
        for p in 0..n_prompts {
            let r = engine.generate(&GenRequest::new(prompts.get(p), p as u64))?;
            secs += r.stats.total_s;
            outs.push(r.latent);
        }
        Ok((outs, secs / n_prompts as f64))
    };

    let mut base_cfg = EngineConfig::new(&model, "baseline", None);
    base_cfg.steps = steps;
    let (base, base_s) = run(&base_cfg)?;
    let fx = FeatureExtractor::new(base[0].len(), 32, 3);

    let mut t = Table::new(&format!("ablation sweep ({model}, {steps} steps)"))
        .headers(&["Ratio", "Variant", "DINOp", "MSE", "s/img", "vs base"]);
    t.row(vec![
        "—".into(),
        "baseline".into(),
        "0.000".into(),
        "0".into(),
        format!("{base_s:.3}"),
        "1.00x".into(),
    ]);

    // uvit_xs ships the full variant set at r=0.5 and the paper grid on
    // uvit_s; sweep whatever the manifest provides.
    for ratio in [0.25, 0.5, 0.75] {
        for variant in ["toma", "toma_stripe", "toma_tile", "toma_once", "tlb"] {
            let mut cfg = EngineConfig::new(&model, variant, Some(ratio));
            cfg.steps = steps;
            if runtime
                .manifest
                .step_name(&model, variant, Some(ratio))
                .is_err()
            {
                continue;
            }
            let (outs, s) = run(&cfg)?;
            let dino = outs
                .iter()
                .zip(&base)
                .map(|(a, b)| dino_proxy(&fx, b, a))
                .sum::<f64>()
                / outs.len() as f64;
            let m = outs
                .iter()
                .zip(&base)
                .map(|(a, b)| mse(b, a))
                .sum::<f64>()
                / outs.len() as f64;
            t.row(vec![
                format!("{ratio:.2}"),
                variant.into(),
                format!("{dino:.3}"),
                format!("{m:.0}"),
                format!("{s:.3}"),
                format!("{:.2}x", base_s / s),
            ]);
        }
    }
    println!("{}", t.render());
    Ok(())
}
