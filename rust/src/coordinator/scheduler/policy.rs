//! Admission policy for the micro-batching scheduler: cohort size, the
//! cohort-formation window, queue bounds (backpressure) and admission
//! deadlines (load shedding).
//!
//! Two policy kinds sit behind [`LanePolicy`]:
//!
//! * [`BatchPolicy`] — static limits, fixed per lane (the PR 2 behavior);
//! * [`AdaptivePolicy`] — the ROADMAP "Scheduler autoscaling" item: the
//!   formation window and batch cap are *derived per formation round*
//!   from the lane's observed inter-arrival times (an EWMA estimate fed
//!   by the lane loop, [`ArrivalEstimator`]) and a p99 latency target,
//!   with overload feedback from a **per-lane exponentially-decayed**
//!   served-latency reservoir ([`DecayedTail`]) — not the
//!   lifetime-cumulative `e2e_time` histogram it replaced, whose
//!   never-forgetting tail forced PR 4's 1/4 shrink floor. Under bursty
//!   arrivals the window widens (up to the latency budget) so cohorts
//!   grow and the Sec. 4.3.2 selection/weights amortization survives; on
//!   an idle lane it collapses to zero so a lone request is never held
//!   waiting for company that will not come.
//!
//! The policy only shapes *queuing* (when a cohort starts and how large
//! it may grow) — never the numeric path, so batched latents stay
//! bit-identical to per-request ones under either kind.

/// Limits governing how a lane forms cohorts and drains its queue.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum cohort size — requests batched into one denoising step.
    pub max_batch: usize,
    /// How long the first request of a new cohort waits for companions
    /// before the cohort starts (the classic batching-window tradeoff:
    /// larger windows raise occupancy, smaller ones bound added latency).
    pub max_queue_wait_s: f64,
    /// Bounded per-lane queue depth; `try_submit` fails fast beyond it
    /// (backpressure), while `submit` blocks.
    pub queue_depth: usize,
    /// Default admission deadline (seconds from submission): a request
    /// still queued after this long is shed with an error instead of
    /// served hopelessly late. Per-request `GenRequest::deadline_s`
    /// overrides it. `None` disables shedding.
    pub deadline_s: Option<f64>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_queue_wait_s: 0.005,
            queue_depth: 256,
            deadline_s: None,
        }
    }
}

impl BatchPolicy {
    /// Policy with a given cohort size cap, defaults elsewhere.
    pub fn with_max_batch(max_batch: usize) -> Self {
        BatchPolicy {
            max_batch,
            ..Default::default()
        }
        .normalized()
    }

    /// Formation windows above this are treated as "wait until the batch
    /// is full": one hour, far beyond any serving cadence, and safely
    /// finite for `Duration::from_secs_f64` (which panics on
    /// non-finite/overflowing input — a lane-killing bug otherwise).
    pub const MAX_QUEUE_WAIT_S: f64 = 3600.0;

    /// Clamp degenerate values to servable bounds.
    pub fn normalized(mut self) -> Self {
        self.max_batch = self.max_batch.max(1);
        self.queue_depth = self.queue_depth.max(1);
        if !(self.max_queue_wait_s >= 0.0) {
            self.max_queue_wait_s = 0.0; // negative or NaN
        }
        if self.max_queue_wait_s > Self::MAX_QUEUE_WAIT_S {
            self.max_queue_wait_s = Self::MAX_QUEUE_WAIT_S; // inf or absurd
        }
        self
    }

    /// Effective admission deadline for a request (request override wins).
    pub fn deadline_for(&self, request_deadline_s: Option<f64>) -> Option<f64> {
        request_deadline_s.or(self.deadline_s)
    }
}

/// Formation parameters for one cohort round, derived by the lane policy:
/// how long the cohort opener waits for companions and how many members
/// the cohort may grow to this round.
#[derive(Clone, Copy, Debug)]
pub struct Formation {
    pub window_s: f64,
    pub max_batch: usize,
}

/// EWMA estimate of a lane's request inter-arrival gap. Driven with
/// explicit offsets (seconds since the lane epoch), never wall-clock
/// reads of its own, so policies derived from it are deterministic under
/// synthetic arrival traces (see the tests below).
#[derive(Clone, Copy, Debug, Default)]
pub struct ArrivalEstimator {
    alpha: f64,
    last_s: Option<f64>,
    ewma_gap_s: Option<f64>,
}

impl ArrivalEstimator {
    pub fn new(alpha: f64) -> ArrivalEstimator {
        ArrivalEstimator {
            alpha: if alpha.is_finite() {
                alpha.clamp(0.01, 1.0)
            } else {
                AdaptivePolicy::DEFAULT_ALPHA
            },
            last_s: None,
            ewma_gap_s: None,
        }
    }

    /// Record an arrival at `t_s` seconds since the lane epoch. Gaps are
    /// clamped non-negative (queue reordering never yields time travel).
    pub fn on_arrival(&mut self, t_s: f64) {
        if let Some(last) = self.last_s {
            let gap = (t_s - last).max(0.0);
            self.ewma_gap_s = Some(match self.ewma_gap_s {
                Some(g) => (1.0 - self.alpha) * g + self.alpha * gap,
                None => gap,
            });
        }
        self.last_s = Some(match self.last_s {
            Some(last) => last.max(t_s),
            None => t_s,
        });
    }

    /// Smoothed inter-arrival gap in seconds (`None` until two arrivals
    /// have been observed — the cold-start case).
    pub fn gap_s(&self) -> Option<f64> {
        self.ewma_gap_s
    }

    /// Smoothed arrival rate in requests/second.
    pub fn rate_hz(&self) -> Option<f64> {
        self.ewma_gap_s.map(|g| 1.0 / g.max(1e-9))
    }
}

/// Exponentially-decayed per-lane latency reservoir: the overload-feedback
/// signal for [`AdaptivePolicy`].
///
/// The lifetime-cumulative `e2e_time` histogram this replaces never
/// forgets: one overload episode kept the served p99 elevated for the
/// lane's whole life, which is why PR 4 floored the adaptive window
/// shrink at 1/4. Here every recorded completion loses half its vote per
/// `half_life_s`, so the p99 tracks *current* load — the floor is gone
/// (see [`AdaptivePolicy::formation`]) — and each lane owns its own
/// reservoir instead of reading a histogram shared across all lanes.
///
/// Like [`ArrivalEstimator`], it is driven with explicit time offsets
/// (seconds since the lane epoch) and never reads wall-clock itself, so
/// policy tests stay deterministic. Because decay scales all bucket
/// weights uniformly, quantiles only move when *new* completions arrive
/// to outweigh old ones; a lane that goes fully idle instead expires —
/// once the decayed total weight falls below a threshold the reservoir
/// reads as empty ([`DecayedTail::p99_at`] returns `None`).
#[derive(Clone, Debug)]
pub struct DecayedTail {
    half_life_s: f64,
    bounds_us: Vec<f64>,
    weights: Vec<f64>,
    total: f64,
    last_s: f64,
    max_us: f64,
}

impl DecayedTail {
    /// Default half-life: a completion loses half its vote every 30 s.
    pub const DEFAULT_HALF_LIFE_S: f64 = 30.0;

    /// Decayed total weight below which the reservoir reads as empty
    /// (a single observation expires after ~10 half-lives).
    const MIN_TOTAL: f64 = 1e-3;

    pub fn new(half_life_s: f64) -> DecayedTail {
        let bounds_us = crate::util::stats::latency_bounds_us();
        let n = bounds_us.len();
        DecayedTail {
            half_life_s: if half_life_s.is_finite() && half_life_s > 0.0 {
                half_life_s
            } else {
                Self::DEFAULT_HALF_LIFE_S
            },
            bounds_us,
            weights: vec![0.0; n + 1],
            total: 0.0,
            last_s: 0.0,
            max_us: 0.0,
        }
    }

    /// Record a served latency `v_s` observed at `now_s` seconds since
    /// the lane epoch (decays everything recorded earlier first). If the
    /// reservoir had fully expired while idle, the history — including
    /// the overflow-bucket maximum, which decay alone never ages out — is
    /// discarded before recording, so a long-faded spike cannot resurface
    /// as the reported tail once traffic resumes.
    pub fn observe(&mut self, now_s: f64, v_s: f64) {
        self.decay_to(now_s);
        if self.total < Self::MIN_TOTAL {
            self.weights.iter_mut().for_each(|w| *w = 0.0);
            self.total = 0.0;
            self.max_us = 0.0;
        }
        let us = v_s.max(0.0) * 1e6;
        let i = self.bounds_us.partition_point(|b| *b < us);
        self.weights[i] += 1.0;
        self.total += 1.0;
        if us > self.max_us {
            self.max_us = us;
        }
    }

    fn decay_to(&mut self, now_s: f64) {
        let dt = (now_s - self.last_s).max(0.0);
        if dt > 0.0 && self.total > 0.0 {
            let f = 0.5f64.powf(dt / self.half_life_s);
            for w in &mut self.weights {
                *w *= f;
            }
            self.total *= f;
            // The overflow bucket reports `max_us`, which a pure weight
            // decay would never age out while the lane stays busy (the
            // expiry reset in `observe` only fires on idle lanes). Fade
            // its excess over the top finite bound on the same half-life,
            // so an ancient extreme spike converges to the bucket
            // boundary instead of being reported as the current tail
            // forever; fresh overflow observations push it back up.
            let top = self.bounds_us.last().copied().unwrap_or(0.0);
            if self.max_us > top {
                self.max_us = top + (self.max_us - top) * f;
            }
        }
        if now_s > self.last_s {
            self.last_s = now_s;
        }
    }

    /// Total decayed weight as seen at `now_s` (read-only virtual decay).
    fn total_at(&self, now_s: f64) -> f64 {
        if self.total <= 0.0 {
            return 0.0;
        }
        let dt = (now_s - self.last_s).max(0.0);
        self.total * 0.5f64.powf(dt / self.half_life_s)
    }

    /// Decayed-weight quantile in seconds; `None` while (effectively)
    /// empty — fresh lanes, and lanes whose history has fully decayed.
    pub fn quantile_s_at(&self, now_s: f64, q: f64) -> Option<f64> {
        if self.total_at(now_s) < Self::MIN_TOTAL {
            return None;
        }
        // Uniform decay cancels out of the quantile itself: rank over the
        // undecayed-relative weights.
        let target = q.clamp(0.0, 1.0) * self.total;
        let mut acc = 0.0;
        for (i, w) in self.weights.iter().enumerate() {
            acc += w;
            if acc >= target {
                let us = if i < self.bounds_us.len() {
                    self.bounds_us[i]
                } else {
                    self.max_us
                };
                return Some(us / 1e6);
            }
        }
        Some(self.max_us / 1e6)
    }

    /// The decayed served p99 — what the adaptive policy feeds on.
    pub fn p99_at(&self, now_s: f64) -> Option<f64> {
        self.quantile_s_at(now_s, 0.99)
    }
}

/// Load-adaptive batch policy: derives each round's formation window and
/// batch cap from the observed arrival gap and a p99 latency target.
///
/// * **burst** (gap ≪ budget): companions are imminent — widen the window
///   to the time needed to gather a full cohort, capped by the budget, so
///   the cohort amortization grows;
/// * **idle** (gap ≥ budget): no companion is expected within the latency
///   budget — collapse the window to zero and serve solo;
/// * **overload feedback**: when the served e2e p99 already exceeds the
///   target, the window is scaled down proportionally, giving the latency
///   budget back to queue draining.
///
/// The `base` [`BatchPolicy`] supplies hard ceilings: the derived batch
/// cap never exceeds `base.max_batch`, the window never exceeds the
/// formation budget (`p99_target_s * window_share`), and `queue_depth` /
/// `deadline_s` apply unchanged.
#[derive(Clone, Copy, Debug)]
pub struct AdaptivePolicy {
    pub base: BatchPolicy,
    /// End-to-end tail-latency target (seconds) the formation window must
    /// respect.
    pub p99_target_s: f64,
    /// EWMA smoothing factor for the inter-arrival estimate, in
    /// (0.01, 1.0].
    pub alpha: f64,
    /// Fraction of the p99 target spendable on cohort formation.
    pub window_share: f64,
}

impl AdaptivePolicy {
    pub const DEFAULT_ALPHA: f64 = 0.2;
    pub const DEFAULT_WINDOW_SHARE: f64 = 0.25;

    pub fn new(base: BatchPolicy, p99_target_s: f64) -> AdaptivePolicy {
        AdaptivePolicy {
            base,
            p99_target_s,
            alpha: Self::DEFAULT_ALPHA,
            window_share: Self::DEFAULT_WINDOW_SHARE,
        }
        .normalized()
    }

    /// Clamp degenerate values to servable bounds (mirrors
    /// [`BatchPolicy::normalized`]).
    pub fn normalized(mut self) -> AdaptivePolicy {
        self.base = self.base.normalized();
        if !(self.p99_target_s > 0.0) || !self.p99_target_s.is_finite() {
            self.p99_target_s = 1.0; // non-positive, NaN or inf
        }
        if !(self.alpha > 0.0) || !self.alpha.is_finite() {
            self.alpha = Self::DEFAULT_ALPHA;
        }
        self.alpha = self.alpha.clamp(0.01, 1.0);
        if !(self.window_share > 0.0 && self.window_share <= 1.0) {
            self.window_share = Self::DEFAULT_WINDOW_SHARE;
        }
        self
    }

    /// The slice of the p99 target spendable waiting for companions.
    pub fn budget_s(&self) -> f64 {
        (self.p99_target_s * self.window_share).min(BatchPolicy::MAX_QUEUE_WAIT_S)
    }

    /// Derive this round's formation window and batch cap.
    /// `observed_p99_s` is the lane's decayed served end-to-end p99
    /// ([`DecayedTail::p99_at`]) — `None` before any completion, or once
    /// an idle lane's history has fully decayed.
    pub fn formation(&self, est: &ArrivalEstimator, observed_p99_s: Option<f64>) -> Formation {
        let budget = self.budget_s();
        let Some(gap) = est.gap_s() else {
            // Cold start: no estimate yet — behave like the static base,
            // but never beyond the latency budget.
            return Formation {
                window_s: self.base.max_queue_wait_s.min(budget),
                max_batch: self.base.max_batch,
            };
        };
        let (mut window_s, max_batch) = if gap <= 0.0 {
            // Back-to-back burst: the cohort fills instantly, no waiting.
            (0.0, self.base.max_batch)
        } else {
            // Companions expected within the formation budget (+1 for the
            // request that opens the cohort). An idle lane (gap ≥ budget)
            // expects none: cap 1, window 0 — waiting only adds latency.
            let expected = (budget / gap).floor();
            let cap = (1.0 + expected).min(self.base.max_batch as f64) as usize;
            let window = ((cap.max(1) - 1) as f64 * gap).min(budget);
            (window, cap.max(1))
        };
        // Overload feedback: already missing the target ⇒ shrink the
        // window proportionally, giving the latency budget back to queue
        // draining. The signal is the lane's *decayed* p99
        // ([`DecayedTail`]), so a past episode fades on its half-life and
        // no shrink floor is needed: a lane currently 10x over target may
        // collapse its window toward zero, and it recovers as soon as the
        // decayed tail does (PR 4's 1/4 floor only existed because the
        // old cumulative histogram could never recover).
        if let Some(p99) = observed_p99_s {
            if p99 > self.p99_target_s {
                window_s *= (self.p99_target_s / p99).min(1.0);
            }
        }
        Formation {
            window_s: window_s.max(0.0),
            max_batch,
        }
    }
}

/// Which batch-formation policy a scheduler lane runs — selected with
/// `--policy static|adaptive` in `toma-serve serve`.
#[derive(Clone, Copy, Debug)]
pub enum LanePolicy {
    /// Fixed formation window and batch cap (the PR 2 behavior).
    Static(BatchPolicy),
    /// Window/cap derived per round from observed arrivals and the p99
    /// target.
    Adaptive(AdaptivePolicy),
}

impl LanePolicy {
    pub fn normalized(self) -> LanePolicy {
        match self {
            LanePolicy::Static(p) => LanePolicy::Static(p.normalized()),
            LanePolicy::Adaptive(a) => LanePolicy::Adaptive(a.normalized()),
        }
    }

    /// The hard bounds shared by both kinds (queue depth, deadlines, the
    /// batch/window ceilings).
    pub fn base(&self) -> &BatchPolicy {
        match self {
            LanePolicy::Static(p) => p,
            LanePolicy::Adaptive(a) => &a.base,
        }
    }

    /// Per-round formation parameters (static kinds ignore the estimate).
    pub fn formation(&self, est: &ArrivalEstimator, observed_p99_s: Option<f64>) -> Formation {
        match self {
            LanePolicy::Static(p) => Formation {
                window_s: p.max_queue_wait_s,
                max_batch: p.max_batch,
            },
            LanePolicy::Adaptive(a) => a.formation(est, observed_p99_s),
        }
    }

    /// A fresh per-lane arrival estimator with this policy's smoothing.
    pub fn estimator(&self) -> ArrivalEstimator {
        match self {
            LanePolicy::Static(_) => ArrivalEstimator::new(AdaptivePolicy::DEFAULT_ALPHA),
            LanePolicy::Adaptive(a) => ArrivalEstimator::new(a.alpha),
        }
    }

    /// Parse the `--policy` CLI value over a configured base.
    pub fn parse(name: &str, base: BatchPolicy, p99_target_s: f64) -> Option<LanePolicy> {
        match name {
            "static" => Some(LanePolicy::Static(base.normalized())),
            "adaptive" => Some(LanePolicy::Adaptive(AdaptivePolicy::new(base, p99_target_s))),
            _ => None,
        }
    }
}

impl From<BatchPolicy> for LanePolicy {
    fn from(p: BatchPolicy) -> LanePolicy {
        LanePolicy::Static(p)
    }
}

impl From<AdaptivePolicy> for LanePolicy {
    fn from(a: AdaptivePolicy) -> LanePolicy {
        LanePolicy::Adaptive(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_servable() {
        let p = BatchPolicy::default();
        assert!(p.max_batch >= 1);
        assert!(p.queue_depth >= 1);
        assert!(p.max_queue_wait_s >= 0.0);
        assert!(p.deadline_s.is_none());
    }

    #[test]
    fn normalized_clamps_degenerate_values() {
        let p = BatchPolicy {
            max_batch: 0,
            max_queue_wait_s: -1.0,
            queue_depth: 0,
            deadline_s: None,
        }
        .normalized();
        assert_eq!(p.max_batch, 1);
        assert_eq!(p.queue_depth, 1);
        assert_eq!(p.max_queue_wait_s, 0.0);
        // NaN windows clamp too (the `!(x >= 0)` form catches them).
        let p = BatchPolicy {
            max_queue_wait_s: f64::NAN,
            ..Default::default()
        }
        .normalized();
        assert_eq!(p.max_queue_wait_s, 0.0);
        // Infinite / absurd windows clamp to the finite cap instead of
        // later panicking Duration::from_secs_f64 in the lane thread.
        for huge in [f64::INFINITY, 1e30] {
            let p = BatchPolicy {
                max_queue_wait_s: huge,
                ..Default::default()
            }
            .normalized();
            assert_eq!(p.max_queue_wait_s, BatchPolicy::MAX_QUEUE_WAIT_S);
        }
    }

    #[test]
    fn request_deadline_overrides_policy() {
        let p = BatchPolicy {
            deadline_s: Some(1.0),
            ..Default::default()
        };
        assert_eq!(p.deadline_for(None), Some(1.0));
        assert_eq!(p.deadline_for(Some(0.2)), Some(0.2));
        let none = BatchPolicy::default();
        assert_eq!(none.deadline_for(None), None);
        assert_eq!(none.deadline_for(Some(3.0)), Some(3.0));
    }

    #[test]
    fn with_max_batch_sets_cap() {
        assert_eq!(BatchPolicy::with_max_batch(4).max_batch, 4);
        assert_eq!(BatchPolicy::with_max_batch(0).max_batch, 1);
    }

    // -- adaptive policy: deterministic arrival traces, no wall-clock --

    fn adaptive() -> AdaptivePolicy {
        // budget = p99_target * share = 1.0 * 0.25 = 0.25 s
        AdaptivePolicy::new(
            BatchPolicy {
                max_batch: 8,
                max_queue_wait_s: 0.5,
                ..Default::default()
            },
            1.0,
        )
    }

    /// Feed a fixed-gap trace: arrivals at 0, gap, 2*gap, ...
    fn trace(alpha: f64, gap_s: f64, n: usize) -> ArrivalEstimator {
        let mut est = ArrivalEstimator::new(alpha);
        for i in 0..n {
            est.on_arrival(i as f64 * gap_s);
        }
        est
    }

    #[test]
    fn adaptive_window_collapses_when_arrivals_are_sparse() {
        let p = adaptive();
        // 1 s gaps, far beyond the 0.25 s budget: no companion expected.
        let est = trace(p.alpha, 1.0, 10);
        let f = p.formation(&est, None);
        assert_eq!(f.window_s, 0.0, "idle lane must not hold the opener");
        assert_eq!(f.max_batch, 1, "no companions ⇒ solo cohort");
    }

    #[test]
    fn adaptive_window_grows_under_burst_within_p99_target() {
        let p = adaptive();
        // 1 ms gaps: a full cohort forms well inside the budget.
        let est = trace(p.alpha, 0.001, 20);
        let f = p.formation(&est, None);
        assert!(f.window_s > 0.0, "burst must open a formation window");
        // Time to gather the 7 companions of an 8-cohort at 1 ms gaps.
        assert!((f.window_s - 0.007).abs() < 1e-9, "window {}", f.window_s);
        assert!(f.window_s <= p.budget_s());
        assert!(f.window_s <= p.p99_target_s, "never beyond the p99 target");
        assert_eq!(f.max_batch, 8, "burst fills up to the configured max");
        // Sparse vs burst ordering: the adaptive window is wider under
        // burst than when idle.
        let sparse = p.formation(&trace(p.alpha, 1.0, 10), None);
        assert!(f.window_s > sparse.window_s);
    }

    #[test]
    fn adaptive_cap_tracks_rate_and_never_exceeds_configured_max() {
        let p = adaptive();
        // 0.1 s gaps against a 0.25 s budget: 2 companions expected.
        let est = trace(p.alpha, 0.1, 10);
        let f = p.formation(&est, None);
        assert_eq!(f.max_batch, 3);
        assert!((f.window_s - 0.2).abs() < 1e-9, "window {}", f.window_s);
        // Even an extreme burst cannot exceed the configured ceiling.
        let f = p.formation(&trace(p.alpha, 1e-6, 50), None);
        assert!(f.max_batch <= p.base.max_batch);
        // Zero-gap (all at once): cohort fills instantly, no waiting.
        let f = p.formation(&trace(p.alpha, 0.0, 5), None);
        assert_eq!(f.window_s, 0.0);
        assert_eq!(f.max_batch, 8);
    }

    #[test]
    fn adaptive_cold_start_uses_base_window_capped_by_budget() {
        let p = adaptive();
        // No arrivals at all, and a single arrival (no gap yet): both are
        // cold starts — static base behavior, clipped to the budget.
        for est in [ArrivalEstimator::new(p.alpha), trace(p.alpha, 0.1, 1)] {
            let f = p.formation(&est, None);
            assert_eq!(f.max_batch, p.base.max_batch);
            assert!((f.window_s - 0.25).abs() < 1e-9, "base 0.5 clips to budget");
        }
    }

    #[test]
    fn adaptive_overload_feedback_shrinks_window_unfloored() {
        let p = adaptive();
        let est = trace(p.alpha, 0.001, 20);
        let relaxed = p.formation(&est, Some(0.5)).window_s; // under target
        let stressed = p.formation(&est, Some(2.0)).window_s; // 2x over
        assert!((relaxed - 0.007).abs() < 1e-9, "meeting the target: no cut");
        assert!((stressed - 0.0035).abs() < 1e-9, "2x over ⇒ half window");
        // The decayed signal recovers on its own, so unlike the PR 4
        // cumulative-histogram feedback there is no 1/4 floor: a lane
        // currently 100x over target cuts formation to 1%.
        let swamped = p.formation(&est, Some(100.0)).window_s;
        assert!((swamped - 0.007 * 0.01).abs() < 1e-9, "100x over ⇒ 1% window");
    }

    // -- decayed per-lane tail: deterministic offset-driven traces --

    #[test]
    fn decayed_tail_p99_tracks_current_load() {
        let mut t = DecayedTail::new(10.0);
        assert!(t.p99_at(0.0).is_none(), "empty reservoir has no signal");
        for i in 0..100 {
            t.observe(i as f64 * 0.01, 2.0); // overloaded: 2 s e2e
        }
        let hot = t.p99_at(1.0).expect("signal");
        assert!(hot > 1.0, "p99 must see the 2 s tail: {hot}");
        // Fast completions 8 half-lives later outweigh the stale tail
        // (the old weight has decayed to ~0.4 of 200 fresh votes).
        for i in 0..200 {
            t.observe(80.0 + i as f64 * 0.01, 0.01);
        }
        let cooled = t.p99_at(82.0).expect("signal");
        assert!(cooled < 0.1, "decayed tail must recover: {cooled}");
    }

    #[test]
    fn decayed_tail_expires_when_idle() {
        let mut t = DecayedTail::new(5.0);
        t.observe(0.0, 3.0);
        let p = t.p99_at(1.0).expect("fresh signal");
        assert!(p >= 2.0 && p < 6.0, "bucketed p99 near 3 s: {p}");
        // Quantiles are decay-invariant while the signal lives (uniform
        // scaling cancels)...
        assert_eq!(t.p99_at(20.0), t.p99_at(1.0));
        // ...but an idle lane's reservoir expires entirely (~10
        // half-lives for a single vote), unlike the cumulative histogram.
        assert!(t.p99_at(300.0).is_none(), "stale signal must expire");
        // And once expired, the first new completion starts a fresh
        // history: the old 3 s spike (and its overflow-style maximum)
        // must not resurface in the reported tail.
        t.observe(300.0, 0.01);
        let fresh = t.p99_at(300.5).expect("fresh signal");
        assert!(fresh < 0.1, "expired history must not resurface: {fresh}");
    }

    #[test]
    fn decayed_tail_overflow_spike_fades_under_sustained_traffic() {
        // The overflow bucket (> the ~56 s top bound) reports `max_us`.
        // An old 600 s spike must not be quoted as the current p99 once
        // sustained (still-slow) traffic has aged it out: the excess over
        // the top bound fades on the half-life. The 30 s gap is 6
        // half-lives — total decays to ~0.016, well above MIN_TOTAL, so
        // the idle expiry reset does NOT fire and this exercises the
        // fade itself: without it, max_us stays 600 s and the first
        // assertion fails.
        let mut t = DecayedTail::new(5.0);
        t.observe(0.0, 600.0);
        for i in 0..100 {
            t.observe(30.0 + i as f64 * 0.01, 60.0); // current tail: 60 s
        }
        let p = t.p99_at(31.5).expect("signal");
        assert!(p < 70.0, "old 600 s spike must have faded: {p}");
        assert!(p > 50.0, "the genuine 60 s overflow tail still shows: {p}");
    }

    #[test]
    fn decayed_tail_clamps_degenerate_half_life_and_time() {
        let mut t = DecayedTail::new(f64::NAN);
        t.observe(5.0, 1.0);
        // Out-of-order reads/writes clamp to non-negative elapsed time.
        t.observe(1.0, 1.0);
        assert!(t.p99_at(0.0).is_some());
        assert!(t.quantile_s_at(5.0, 0.5).expect("median") > 0.5);
    }

    #[test]
    fn estimator_ewma_tracks_burst_transitions() {
        let mut est = ArrivalEstimator::new(0.2);
        assert!(est.gap_s().is_none(), "cold start has no estimate");
        est.on_arrival(0.0);
        assert!(est.gap_s().is_none(), "one arrival is still no gap");
        for i in 1..=5 {
            est.on_arrival(i as f64);
        }
        let sparse_gap = est.gap_s().expect("estimate");
        assert!((sparse_gap - 1.0).abs() < 1e-12);
        // A burst pulls the EWMA down monotonically toward the new gap.
        let mut t = 5.0;
        let mut prev = sparse_gap;
        for _ in 0..20 {
            t += 0.001;
            est.on_arrival(t);
            let g = est.gap_s().expect("estimate");
            assert!(g < prev, "EWMA must decrease through the burst");
            prev = g;
        }
        assert!(prev < 0.1, "after 20 burst arrivals the gap is small");
        // Out-of-order timestamps clamp to non-negative gaps.
        est.on_arrival(t - 1.0);
        assert!(est.gap_s().expect("estimate") >= 0.0);
    }

    #[test]
    fn adaptive_normalized_clamps_degenerate_values() {
        let p = AdaptivePolicy {
            base: BatchPolicy {
                max_batch: 0,
                ..Default::default()
            },
            p99_target_s: f64::NAN,
            alpha: -3.0,
            window_share: 7.0,
        }
        .normalized();
        assert_eq!(p.base.max_batch, 1);
        assert_eq!(p.p99_target_s, 1.0);
        assert_eq!(p.alpha, AdaptivePolicy::DEFAULT_ALPHA);
        assert_eq!(p.window_share, AdaptivePolicy::DEFAULT_WINDOW_SHARE);
        // LanePolicy plumbing: parse + base + From.
        let base = BatchPolicy::default();
        assert!(matches!(
            LanePolicy::parse("static", base, 1.0),
            Some(LanePolicy::Static(_))
        ));
        assert!(matches!(
            LanePolicy::parse("adaptive", base, 1.0),
            Some(LanePolicy::Adaptive(_))
        ));
        assert!(LanePolicy::parse("bogus", base, 1.0).is_none());
        let lp: LanePolicy = base.into();
        assert_eq!(lp.base().max_batch, base.max_batch);
        let f = lp.formation(&ArrivalEstimator::new(0.2), None);
        assert_eq!(f.max_batch, base.max_batch);
        assert_eq!(f.window_s, base.max_queue_wait_s);
    }
}
