//! Request / result types for the serving coordinator.

use crate::toma::plan::ReuseSchedule;

/// Engine configuration: one engine per (model, variant, ratio, schedule).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub model: String,
    /// "baseline", "toma", "toma_stripe", "toma_tile", "toma_once",
    /// "toma_pinv", "toma_colsm", "tlb", "tome", "tofu", "todo".
    pub variant: String,
    pub ratio: Option<f64>,
    pub steps: usize,
    /// Classifier-free guidance weight.
    pub guidance: f32,
    pub schedule: ReuseSchedule,
    /// Destination-selection mode: "tile" | "stripe" | "global" | "random".
    pub select_mode: String,
}

impl EngineConfig {
    pub fn new(model: &str, variant: &str, ratio: Option<f64>) -> Self {
        EngineConfig {
            model: model.to_string(),
            variant: variant.to_string(),
            ratio,
            steps: 50,
            guidance: 5.0,
            schedule: ReuseSchedule::default(),
            select_mode: "tile".to_string(),
        }
    }

    /// Does this variant consume ToMA merge weights at runtime?
    pub fn needs_plan(&self) -> bool {
        self.variant.starts_with("toma")
    }

    /// Cache / batch key.
    pub fn key(&self) -> String {
        format!(
            "{}:{}:{}:{}:{}+{}",
            self.model,
            self.variant,
            self.ratio.map(|r| format!("{r:.2}")).unwrap_or_default(),
            self.select_mode,
            self.schedule.dest_every,
            self.schedule.weight_every
        )
    }
}

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: String,
    pub seed: u64,
    /// Record per-step destination sets (Fig. 4) and plan stats.
    pub trace: bool,
}

impl GenRequest {
    pub fn new(prompt: &str, seed: u64) -> Self {
        GenRequest {
            prompt: prompt.to_string(),
            seed,
            trace: false,
        }
    }
}

/// Timing + cache statistics for one generation.
#[derive(Clone, Debug, Default)]
pub struct GenStats {
    pub total_s: f64,
    pub select_s: f64,
    pub step_s: f64,
    pub host_s: f64,
    pub steps: usize,
    pub select_calls: usize,
    pub weight_refreshes: usize,
    pub plan_reuses: usize,
}

/// Result of one generation.
#[derive(Clone, Debug)]
pub struct GenResult {
    /// Final denoised latent for the conditional row, (C, H, W) flattened.
    pub latent: Vec<f32>,
    pub stats: GenStats,
    /// Per-step global destination-token sets (only when trace=true).
    pub dest_trace: Vec<Vec<usize>>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn needs_plan_per_variant() {
        for v in ["toma", "toma_stripe", "toma_tile", "toma_once", "toma_pinv"] {
            assert!(EngineConfig::new("m", v, Some(0.5)).needs_plan(), "{v}");
        }
        for v in ["baseline", "tlb", "tome", "tofu", "todo"] {
            assert!(!EngineConfig::new("m", v, Some(0.5)).needs_plan(), "{v}");
        }
    }

    #[test]
    fn key_distinguishes_configs() {
        let a = EngineConfig::new("uvit_s", "toma", Some(0.5));
        let mut b = a.clone();
        b.ratio = Some(0.25);
        assert_ne!(a.key(), b.key());
        let mut c = a.clone();
        c.schedule.dest_every = 1;
        assert_ne!(a.key(), c.key());
    }
}
