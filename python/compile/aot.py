"""AOT lowering driver: JAX models -> HLO text + weights + manifest.

Run once at build time (``make artifacts``); Python never executes on the
request path. For every artifact in ``configs.enumerate_artifacts`` this
emits:

  artifacts/<name>.hlo.txt        HLO *text* (NOT .serialize(): jax >= 0.5
                                  emits 64-bit instruction ids that
                                  xla_extension 0.5.1 rejects; the text
                                  parser reassigns ids cleanly)
  artifacts/weights/<model>.npz   all parameters, named by flatten path
  artifacts/manifest.json         artifact index the Rust runtime parses:
                                  input order (params first, in tree-flatten
                                  order, then runtime inputs), shapes,
                                  dtypes, variant metadata, model configs.

Usage:  python -m compile.aot --out-dir ../artifacts [--quick]
                              [--models uvit_xs,uvit_s,dit_s] [--pallas]
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import baselines_jax, dit as dit_mod, model as uvit_mod, toma_jax
from .configs import (MODELS, TAU, DEST_EVERY, WEIGHT_EVERY, DitConfig,
                      UVitConfig, enumerate_artifacts, ratio_tag, tiles_for)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def flatten_params(params):
    """-> (names, leaves) in jax tree-flatten order ("blocks.0.qkv.w")."""
    leaves, _ = jax.tree_util.tree_flatten(params)
    paths = jax.tree_util.tree_flatten_with_path(params)[0]

    def name(path):
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return ".".join(parts)

    names = [name(path) for path, _ in paths]
    assert len(names) == len(leaves)
    return names, leaves


def spec_of(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def dtype_tag(dt):
    return {"float32": "f32", "int32": "s32", "uint32": "u32"}[str(np.dtype(dt))]


def region_spec(cfg, mode, regions):
    return toma_jax.RegionSpec(mode=mode, regions=regions,
                               grid_h=cfg.grid, grid_w=cfg.grid)


def dloc(cfg, spec, ratio):
    n_loc = spec.tokens // spec.regions
    return max(1, int(round((1.0 - ratio) * n_loc)))


# ---------------------------------------------------------------------------
# Artifact builders: return (fn, runtime_inputs [(name, spec)], outputs meta)
# ---------------------------------------------------------------------------

def build_step(cfg, art, kernel_impl):
    b = cfg.batch
    x_spec = jax.ShapeDtypeStruct((b, cfg.channels, cfg.latent_hw,
                                   cfg.latent_hw), jnp.float32)
    t_spec = jax.ShapeDtypeStruct((b,), jnp.float32)
    c_spec = jax.ShapeDtypeStruct((b, cfg.txt_len, cfg.txt_dim), jnp.float32)

    is_dit = isinstance(cfg, DitConfig)
    v = art.variant

    if is_dit:
        return build_dit_step(cfg, art, kernel_impl, x_spec, t_spec, c_spec)

    if v == "baseline":
        def fn(params, x_t, t, cond):
            return (uvit_mod.apply_uvit(params, cfg, x_t, t, cond,
                                        "baseline", None, kernel_impl),)
        return fn, [("x_t", x_spec), ("t", t_spec), ("cond", c_spec)]

    if v in ("toma", "toma_stripe", "toma_tile", "toma_once",
             "toma_pinv", "toma_colsm"):
        mode = art.region_mode if art.regions > 1 else "global"
        spec = region_spec(cfg, mode, max(1, art.regions))
        d = dloc(cfg, spec, art.ratio)
        n_loc = spec.tokens // spec.regions
        g = b * spec.regions
        at_spec = jax.ShapeDtypeStruct((g, d, n_loc), jnp.float32)
        unmerge = {"toma_pinv": "pinv", "toma_colsm": "colsoftmax"}.get(
            v, "transpose")
        base_variant = "toma_once" if v == "toma_once" else "toma"

        if unmerge == "colsoftmax":
            def fn(params, x_t, t, cond, a, a_tilde):
                m = toma_jax.Merger(a, a_tilde, spec, b, kernel_impl,
                                    unmerge)
                return (uvit_mod.apply_uvit(params, cfg, x_t, t, cond,
                                            base_variant, m, kernel_impl),)
            return fn, [("x_t", x_spec), ("t", t_spec), ("cond", c_spec),
                        ("a", at_spec), ("a_tilde", at_spec)]

        def fn(params, x_t, t, cond, a_tilde):
            m = toma_jax.Merger(None, a_tilde, spec, b, kernel_impl, unmerge)
            return (uvit_mod.apply_uvit(params, cfg, x_t, t, cond,
                                        base_variant, m, kernel_impl),)
        return fn, [("x_t", x_spec), ("t", t_spec), ("cond", c_spec),
                    ("a_tilde", at_spec)]

    if v == "tlb":
        m = toma_jax.tlb_merger(b, cfg.tokens, art.ratio)

        def fn(params, x_t, t, cond):
            return (uvit_mod.apply_uvit(params, cfg, x_t, t, cond, "tlb",
                                        m, kernel_impl),)
        return fn, [("x_t", x_spec), ("t", t_spec), ("cond", c_spec)]

    if v in ("tome", "tofu"):
        ratio, depth = art.ratio, cfg.depth

        def factory(x, bi):
            mode = "merge"
            if v == "tofu":
                # ToFu: merge while features are near-linear (early blocks),
                # prune later (static stand-in for the linearity test).
                mode = "merge" if bi < depth // 2 else "prune"
            plan = baselines_jax.tome_plan(x, cfg.grid, cfg.grid, ratio,
                                           mode)
            return baselines_jax.TomeMerger(plan, cfg.tokens)

        def fn(params, x_t, t, cond):
            return (uvit_mod.apply_uvit(params, cfg, x_t, t, cond, v,
                                        factory, kernel_impl),)
        return fn, [("x_t", x_spec), ("t", t_spec), ("cond", c_spec)]

    if v == "todo":
        def fn(params, x_t, t, cond):
            return (uvit_mod.apply_uvit(params, cfg, x_t, t, cond, "todo",
                                        None, kernel_impl),)
        return fn, [("x_t", x_spec), ("t", t_spec), ("cond", c_spec)]

    raise ValueError(f"unknown variant {v}")


def build_dit_step(cfg, art, kernel_impl, x_spec, t_spec, c_spec):
    b = cfg.batch
    v = art.variant
    if v == "baseline":
        def fn(params, x_t, t, cond):
            return (dit_mod.apply_dit(params, cfg, x_t, t, cond, None,
                                      kernel_impl),)
        return fn, [("x_t", x_spec), ("t", t_spec), ("cond", c_spec)]

    assert v in ("toma", "toma_tile")
    mode = "tile" if v == "toma_tile" else "global"
    regions = art.regions if v == "toma_tile" else 1
    img_spec = region_spec(cfg, mode, regions)
    d_img = dloc(cfg, img_spec, art.ratio)
    n_loc = img_spec.tokens // img_spec.regions
    g = b * img_spec.regions
    txt_spec = toma_jax.RegionSpec("global", 1, 1, cfg.txt_len)
    d_txt = max(1, int(round((1.0 - art.ratio) * cfg.txt_len)))

    at_img_spec = jax.ShapeDtypeStruct((g, d_img, n_loc), jnp.float32)
    ix_img_spec = jax.ShapeDtypeStruct((g, d_img), jnp.int32)
    at_txt_spec = jax.ShapeDtypeStruct((b, d_txt, cfg.txt_len), jnp.float32)
    ix_txt_spec = jax.ShapeDtypeStruct((b, d_txt), jnp.int32)

    reg_index = None
    if img_spec.regions > 1:
        reg_index = toma_jax.region_token_index(img_spec)  # (P, N_loc)

    def fn(params, x_t, t, cond, at_img, ix_img, at_txt, ix_txt):
        m_img = toma_jax.Merger(None, at_img, img_spec, b, kernel_impl)
        m_txt = toma_jax.Merger(None, at_txt, txt_spec, b, kernel_impl)
        # Global phase-table positions of the selected destinations.
        if reg_index is not None:
            gl = reg_index[None, :, :]                    # (1, P, N_loc)
            gl = jnp.broadcast_to(gl, (b, img_spec.regions, n_loc))
            gl = gl.reshape(g, n_loc)
            img_pos = jnp.take_along_axis(gl, ix_img, axis=-1)
            img_pos = img_pos.reshape(b, img_spec.regions * d_img)
        else:
            img_pos = ix_img.reshape(b, d_img)
        img_pos = img_pos + cfg.txt_len                   # offset past text
        txt_pos = ix_txt
        ms = dit_mod.DitMergeState(m_txt, m_img, txt_pos, img_pos)
        return (dit_mod.apply_dit(params, cfg, x_t, t, cond, ms,
                                  kernel_impl),)

    return fn, [("x_t", x_spec), ("t", t_spec), ("cond", c_spec),
                ("at_img", at_img_spec), ("ix_img", ix_img_spec),
                ("at_txt", at_txt_spec), ("ix_txt", ix_txt_spec)]


# Parameter subsets used by non-step artifacts. The stablehlo->XLA
# conversion prunes unused parameters, so each artifact must be lowered
# with exactly the parameters its graph touches; the manifest records the
# resulting order for the Rust runtime.
SELECT_PARAM_KEYS = ["patch", "pos", "time1", "time2"]


def build_select(cfg, art, kernel_impl):
    """Selection artifact: hidden states -> (idx, A, A~) [per modality]."""
    b = cfg.batch
    x_spec = jax.ShapeDtypeStruct((b, cfg.channels, cfg.latent_hw,
                                   cfg.latent_hw), jnp.float32)
    t_spec = jax.ShapeDtypeStruct((b,), jnp.float32)
    is_dit = isinstance(cfg, DitConfig)
    mode = "global" if art.mode in ("global", "random") else art.mode
    spec = region_spec(cfg, mode, art.regions if mode != "global" else 1)
    ratio = art.ratio

    if not is_dit:
        if art.mode == "random":
            s_spec = jax.ShapeDtypeStruct((1,), jnp.uint32)

            def fn(params, x_t, t, seed):
                h = uvit_mod.embed_tokens(params, cfg, x_t, t)
                idx = toma_jax.select_destinations(h, spec, ratio,
                                                   kernel_impl, seed)
                a, at = toma_jax.build_merge_weights(h, idx, spec, TAU,
                                                     kernel_impl)
                return idx, a, at
            return fn, [("x_t", x_spec), ("t", t_spec), ("seed", s_spec)], \
                SELECT_PARAM_KEYS

        def fn(params, x_t, t):
            h = uvit_mod.embed_tokens(params, cfg, x_t, t)
            idx = toma_jax.select_destinations(h, spec, ratio, kernel_impl)
            a, at = toma_jax.build_merge_weights(h, idx, spec, TAU,
                                                 kernel_impl)
            return idx, a, at
        return fn, [("x_t", x_spec), ("t", t_spec)], SELECT_PARAM_KEYS

    # DiT: select image and text destinations independently (App. E).
    c_spec = jax.ShapeDtypeStruct((b, cfg.txt_len, cfg.txt_dim), jnp.float32)
    txt_spec = toma_jax.RegionSpec("global", 1, 1, cfg.txt_len)

    def fn(params, x_t, cond):
        from .model import linear, patchify
        img_h = linear(params["patch"], patchify(x_t, cfg))
        txt_h = linear(params["txt_in"], cond)
        ix_img = toma_jax.select_destinations(img_h, spec, ratio,
                                              kernel_impl)
        a_i, at_i = toma_jax.build_merge_weights(img_h, ix_img, spec, TAU,
                                                 kernel_impl)
        ix_txt = toma_jax.select_destinations(txt_h, txt_spec, ratio,
                                              kernel_impl)
        a_t, at_t = toma_jax.build_merge_weights(txt_h, ix_txt, txt_spec,
                                                 TAU, kernel_impl)
        return ix_img, a_i, at_i, ix_txt, a_t, at_t
    # Note: no timestep input — DiT selection runs on the patch embedding
    # only (time conditioning enters via adaLN inside the blocks).
    return fn, [("x_t", x_spec), ("cond", c_spec)], ["patch", "txt_in"]


def build_weights_only(cfg, art, kernel_impl):
    """Weights-only rebuild: (x_t, t, idx) -> (A, A~) with destinations kept.

    The runtime half of Sec. 4.3.2's split schedule ("destinations every 10
    steps, weights every 5"): the coordinator reruns this cheaper artifact
    on weight-refresh steps instead of the full greedy selection.
    UVit models only (the paper does not reuse across steps on Flux).
    """
    b = cfg.batch
    x_spec = jax.ShapeDtypeStruct((b, cfg.channels, cfg.latent_hw,
                                   cfg.latent_hw), jnp.float32)
    t_spec = jax.ShapeDtypeStruct((b,), jnp.float32)
    mode = "global" if art.mode in ("global", "random") else art.mode
    spec = region_spec(cfg, mode, art.regions if mode != "global" else 1)
    d = dloc(cfg, spec, art.ratio)
    g = b * spec.regions
    ix_spec = jax.ShapeDtypeStruct((g, d), jnp.int32)

    def fn(params, x_t, t, idx):
        h = uvit_mod.embed_tokens(params, cfg, x_t, t)
        a, at = toma_jax.build_merge_weights(h, idx, spec, TAU, kernel_impl)
        return a, at
    return fn, [("x_t", x_spec), ("t", t_spec), ("idx", ix_spec)], \
        SELECT_PARAM_KEYS


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def lower_artifact(fn, params_spec, inputs, out_path):
    """Lower and dump HLO text; returns (n_hlo_params, out_info).

    Asserts the stablehlo->XLA conversion did not prune any parameter: the
    Rust runtime feeds buffers positionally, so every lowered artifact must
    consume exactly (params + runtime inputs).
    """
    arg_specs = [params_spec] + [s for _, s in inputs]
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    n_params = len(comp.program_shape().parameter_shapes())
    n_leaves = len(jax.tree_util.tree_leaves(params_spec))
    expected = n_leaves + len(inputs)
    if n_params != expected:
        raise RuntimeError(
            f"{out_path}: lowered program has {n_params} parameters, "
            f"expected {expected} ({n_leaves} weights + {len(inputs)} "
            f"inputs). A weight was pruned; narrow the param subset.")
    with open(out_path, "w") as f:
        f.write(comp.as_hlo_text())
    return n_params, lowered.out_info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="uvit_xs-only artifact set (pytest / CI)")
    ap.add_argument("--models", default=None,
                    help="comma list of models to lower")
    ap.add_argument("--pallas", action="store_true",
                    help="additionally emit Pallas-kernel artifacts "
                         "(interpret mode) for uvit_xs")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(os.path.join(out_dir, "weights"), exist_ok=True)

    model_names = args.models.split(",") if args.models else None
    steps, selects = enumerate_artifacts(model_names, quick=args.quick)

    # --- weights ----------------------------------------------------------
    manifest = {"tau": TAU, "dest_every": DEST_EVERY,
                "weight_every": WEIGHT_EVERY, "models": {}, "artifacts": []}
    params_by_model, spec_by_model, names_by_model = {}, {}, {}
    wanted = {a.model for a in steps} | {a.model for a in selects}
    for mname in sorted(wanted):
        cfg = MODELS[mname]
        t0 = time.time()
        if isinstance(cfg, DitConfig):
            params = dit_mod.init_dit(cfg, seed=0)
        else:
            params = uvit_mod.init_uvit(cfg, seed=0)
        names, leaves = flatten_params(params)
        np.savez(os.path.join(out_dir, "weights", f"{mname}.npz"),
                 **{n: np.asarray(l) for n, l in zip(names, leaves)})
        params_by_model[mname] = params
        spec_by_model[mname] = jax.tree_util.tree_map(spec_of, params)
        names_by_model[mname] = [
            {"name": n, "shape": list(l.shape), "dtype": dtype_tag(l.dtype)}
            for n, l in zip(names, leaves)]
        mcfg = {"kind": "dit" if isinstance(cfg, DitConfig) else "uvit",
                "latent_hw": cfg.latent_hw, "channels": cfg.channels,
                "patch": cfg.patch, "dim": cfg.dim, "heads": cfg.heads,
                "txt_len": cfg.txt_len, "txt_dim": cfg.txt_dim,
                "batch": cfg.batch, "tokens": cfg.tokens,
                "params": names_by_model[mname]}
        if isinstance(cfg, DitConfig):
            mcfg["joint_blocks"] = cfg.joint_blocks
            mcfg["single_blocks"] = cfg.single_blocks
            mcfg["skip_blocks"] = cfg.skip_blocks
        else:
            mcfg["depth"] = cfg.depth
        manifest["models"][mname] = mcfg
        print(f"[weights] {mname}: {len(names)} tensors "
              f"({sum(np.asarray(l).size for l in leaves):,} scalars, "
              f"{time.time() - t0:.1f}s)")

    # --- artifacts --------------------------------------------------------
    def emit(art, kind, fn, inputs, extra, kernel_impl, param_keys=None):
        name = art.name if not extra.get("pallas") else art.name + "_pallas"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        t0 = time.time()
        full_spec = spec_by_model[art.model]
        if param_keys is None:
            spec = full_spec
        else:
            spec = {k: full_spec[k] for k in param_keys}
        pnames, _ = flatten_params(spec)
        _, out_info = lower_artifact(fn, spec, inputs, path)
        outs = jax.tree_util.tree_leaves(out_info)
        entry = {
            "name": name, "kind": kind, "model": art.model,
            "file": f"{name}.hlo.txt", "kernel_impl": kernel_impl,
            "params": pnames,
            "inputs": [{"name": n, "shape": list(s.shape),
                        "dtype": dtype_tag(s.dtype)} for n, s in inputs],
            "outputs": [{"shape": list(o.shape),
                         "dtype": dtype_tag(o.dtype)} for o in outs],
        }
        entry.update(extra)
        manifest["artifacts"].append(entry)
        print(f"[lower] {name} ({time.time() - t0:.1f}s)")

    for art in steps:
        cfg = MODELS[art.model]
        fn, inputs = build_step(cfg, art, "jnp")
        emit(art, "step", fn, inputs,
             {"variant": art.variant, "ratio": art.ratio,
              "regions": art.regions, "region_mode": art.region_mode},
             "jnp")
    for art in selects:
        cfg = MODELS[art.model]
        fn, inputs, pkeys = build_select(cfg, art, "jnp")
        emit(art, "select", fn, inputs,
             {"mode": art.mode, "ratio": art.ratio, "regions": art.regions},
             "jnp", param_keys=pkeys)
        if not isinstance(cfg, DitConfig) and art.mode != "random":
            wfn, winputs, wkeys = build_weights_only(cfg, art, "jnp")

            class _W:  # reuse emit(): name derives from select's name
                model = art.model
                name = art.name.replace("_select_", "_weights_")
            emit(_W, "weights", wfn, winputs,
                 {"mode": art.mode, "ratio": art.ratio,
                  "regions": art.regions}, "jnp", param_keys=wkeys)

    if args.pallas:
        # Pallas-kernel variants of the hot artifacts (numerics-identical,
        # TPU-shaped path) for cross-checking through the Rust runtime.
        from .configs import StepArtifact, SelectArtifact
        cfg = MODELS["uvit_xs"]
        art = StepArtifact("uvit_xs", "toma", 0.5, 1, "global")
        fn, inputs = build_step(cfg, art, "pallas")
        emit(art, "step", fn, inputs,
             {"variant": "toma", "ratio": 0.5, "regions": 1,
              "region_mode": "global", "pallas": True}, "pallas")
        sart = SelectArtifact("uvit_xs", "tile", 0.5, tiles_for(cfg))
        fn, inputs, pkeys = build_select(cfg, sart, "pallas")
        emit(sart, "select", fn, inputs,
             {"mode": "tile", "ratio": 0.5, "regions": tiles_for(cfg),
              "pallas": True}, "pallas", param_keys=pkeys)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[manifest] {len(manifest['artifacts'])} artifacts -> {out_dir}")


if __name__ == "__main__":
    main()
