//! Tiny CLI argument parser (the vendored crate set has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed getters and an auto-generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    present: Vec<String>,
    spec: Vec<(String, String)>, // (name, help) for usage
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Self {
        let mut a = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                    a.present.push(k.to_string());
                } else {
                    let key = rest.to_string();
                    a.present.push(key.clone());
                    // Treat the next token as a value unless it is a flag.
                    if let Some(next) = it.peek() {
                        if !next.starts_with("--") {
                            a.flags.insert(key, it.next().unwrap());
                            continue;
                        }
                    }
                    a.flags.insert(key, String::from("true"));
                }
            } else {
                a.positional.push(arg);
            }
        }
        a
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn describe(&mut self, name: &str, help: &str) -> &mut Self {
        self.spec.push((name.to_string(), help.to_string()));
        self
    }

    pub fn usage(&self, prog: &str) -> String {
        let mut s = format!("usage: {prog} [options]\n");
        for (n, h) in &self.spec {
            s.push_str(&format!("  --{n:<20} {h}\n"));
        }
        s
    }

    pub fn has(&self, key: &str) -> bool {
        self.present.iter().any(|k| k == key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list value.
    pub fn get_list(&self, key: &str) -> Vec<String> {
        self.get(key)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn flags_and_values() {
        let a = parse("cmd --steps 50 --ratio=0.5 --verbose --out x.txt");
        assert_eq!(a.positional, vec!["cmd"]);
        assert_eq!(a.get_usize("steps", 0), 50);
        assert_eq!(a.get_f64("ratio", 0.0), 0.5);
        assert!(a.has("verbose"));
        assert_eq!(a.get_str("out", ""), "x.txt");
    }

    #[test]
    fn bare_flag_before_flag() {
        let a = parse("--quick --steps 10");
        assert!(a.has("quick"));
        assert_eq!(a.get_usize("steps", 0), 10);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_str("missing", "d"), "d");
        assert!(!a.has("missing"));
    }

    #[test]
    fn list_values() {
        let a = parse("--models uvit_s,dit_s");
        assert_eq!(a.get_list("models"), vec!["uvit_s", "dit_s"]);
    }

    #[test]
    fn negative_number_value() {
        // A negative numeric value is not a flag.
        let a = Args::parse(vec!["--offset".to_string(), "-3".to_string()]);
        // "-3" does not start with "--", so it is consumed as the value.
        assert_eq!(a.get_str("offset", ""), "-3");
    }
}
