//! Threaded serving front-end: a request queue + a worker pool per engine
//! key. Requests with the same (model, variant, ratio, schedule) share a
//! lane; distinct keys get their own lane.
//!
//! The `xla` crate's PJRT handles are deliberately single-threaded (`Rc` +
//! raw pointers), so each worker thread owns a full `Runtime` + `Engine` —
//! the same isolation a per-device worker process has in a production
//! serving stack. Requests and completions are plain `Send` data.
//! (std threads + channels: the vendored crate set has no tokio; the
//! workload is compute-bound through PJRT, so a thread pool is the right
//! shape anyway.)

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::anyhow;
use crate::util::error::Result;

use super::engine::Engine;
use super::metrics::Metrics;
use super::request::{EngineConfig, GenRequest, GenResult};
use crate::runtime::Runtime;

/// A completed request with timing info.
pub struct Completion {
    pub request: GenRequest,
    pub result: Result<GenResult>,
    pub queued_s: f64,
    pub service_s: f64,
}

struct Job {
    request: GenRequest,
    enqueued: Instant,
    done: Sender<Completion>,
}

/// One worker lane: a bounded job queue drained by N engine-owning
/// threads. The bound provides backpressure: [`Server::submit`] blocks at
/// the high-water mark, [`Server::try_submit`] fails fast.
struct Lane {
    tx: SyncSender<Job>,
    handles: Vec<JoinHandle<()>>,
    /// Identity of this lane incarnation. Dead-lane eviction is
    /// generation-checked: a submitter that observed generation `g` fail
    /// may only evict generation `g` — never a lane respawned (g+1) by a
    /// concurrent submitter in the window between the failed send and the
    /// eviction (the ROADMAP "stale sender evicts healthy lane" race).
    generation: u64,
}

pub struct Server {
    artifact_dir: PathBuf,
    pub metrics: Arc<Metrics>,
    workers_per_lane: usize,
    queue_depth: usize,
    lanes: Mutex<BTreeMap<String, Lane>>,
    next_generation: std::sync::atomic::AtomicU64,
}

impl Server {
    pub fn new(artifact_dir: PathBuf, workers_per_lane: usize) -> Server {
        Server {
            artifact_dir,
            metrics: Arc::new(Metrics::new()),
            workers_per_lane: workers_per_lane.max(1),
            queue_depth: 1024,
            lanes: Mutex::new(BTreeMap::new()),
            next_generation: std::sync::atomic::AtomicU64::new(1),
        }
    }

    pub fn with_default_dir(workers_per_lane: usize) -> Server {
        Server::new(crate::default_artifact_dir(), workers_per_lane)
    }

    /// Bound each lane's queue (backpressure watermark). Applies to lanes
    /// spawned after the call.
    pub fn with_queue_depth(mut self, depth: usize) -> Server {
        self.queue_depth = depth.max(1);
        self
    }

    fn spawn_lane(&self, cfg: &EngineConfig) -> Lane {
        let (tx, rx) = sync_channel::<Job>(self.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = vec![];
        for w in 0..self.workers_per_lane {
            let rx: Arc<Mutex<Receiver<Job>>> = rx.clone();
            let metrics = self.metrics.clone();
            let cfg = cfg.clone();
            let dir = self.artifact_dir.clone();
            let name = format!("toma-worker-{w}");
            handles.push(
                std::thread::Builder::new()
                    .name(name)
                    .spawn(move || {
                        // Each worker owns its PJRT client + compiled
                        // executables for the lifetime of the lane.
                        let engine = Runtime::new(dir)
                            .map(Arc::new)
                            .and_then(|rt| Engine::new(rt, cfg.clone()));
                        let engine = match engine {
                            Ok(e) => e,
                            Err(e) => {
                                // Fail every job this worker would serve.
                                let msg = format!("engine init failed: {e:#}");
                                loop {
                                    let job = match rx.lock().unwrap().recv() {
                                        Ok(j) => j,
                                        Err(_) => return,
                                    };
                                    metrics.inc("requests_err");
                                    let _ = job.done.send(Completion {
                                        request: job.request,
                                        result: Err(anyhow!("{msg}")),
                                        queued_s: 0.0,
                                        service_s: 0.0,
                                    });
                                }
                            }
                        };
                        loop {
                            let job = {
                                let guard = rx.lock().unwrap();
                                match guard.recv() {
                                    Ok(j) => j,
                                    Err(_) => return, // queue closed
                                }
                            };
                            let queued_s = job.enqueued.elapsed().as_secs_f64();
                            metrics.observe_s("queue_wait", queued_s);
                            let t0 = Instant::now();
                            let result = engine.generate(&job.request);
                            let service_s = t0.elapsed().as_secs_f64();
                            metrics.observe_s("service_time", service_s);
                            metrics.inc(if result.is_ok() {
                                "requests_ok"
                            } else {
                                "requests_err"
                            });
                            if let Ok(r) = &result {
                                metrics.observe_s("select_time", r.stats.select_s);
                                metrics.add("plan_reuses", r.stats.plan_reuses as u64);
                                metrics.add("select_calls", r.stats.select_calls as u64);
                            }
                            let _ = job.done.send(Completion {
                                request: job.request,
                                result,
                                queued_s,
                                service_s,
                            });
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        let generation = self
            .next_generation
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Lane {
            tx,
            handles,
            generation,
        }
    }

    /// The lane's sender plus the generation it belongs to — the identity
    /// a failed submit must present to [`Server::evict_lane`].
    fn lane_tx(&self, cfg: &EngineConfig) -> (SyncSender<Job>, u64) {
        let mut lanes = self.lanes.lock().unwrap();
        let lane = lanes
            .entry(cfg.key())
            .or_insert_with(|| self.spawn_lane(cfg));
        (lane.tx.clone(), lane.generation)
    }

    /// Remove the lane for `key` only if it is still the `generation` the
    /// caller observed failing. Returns whether a lane was evicted; a
    /// fresher lane (respawned by a concurrent submitter) is left alone.
    fn evict_lane(&self, key: &str, generation: u64) -> bool {
        let mut lanes = self.lanes.lock().unwrap();
        if lanes.get(key).map(|l| l.generation) == Some(generation) {
            lanes.remove(key);
            true
        } else {
            false
        }
    }

    /// Submit a request; the completion arrives on the returned channel.
    /// Blocks when the lane queue is at its bound (backpressure). A dead
    /// lane (panicked workers) fails the request with an error completion
    /// and is respawned on the next submit.
    pub fn submit(&self, cfg: &EngineConfig, request: GenRequest) -> Receiver<Completion> {
        let (tx, generation) = self.lane_tx(cfg);
        let (done_tx, done_rx) = channel();
        self.metrics.inc("requests_submitted");
        let job = Job {
            request,
            enqueued: Instant::now(),
            done: done_tx,
        };
        if let Err(std::sync::mpsc::SendError(job)) = tx.send(job) {
            self.metrics.inc("requests_err");
            self.evict_lane(&cfg.key(), generation);
            let _ = job.done.send(Completion {
                request: job.request,
                result: Err(anyhow!("server lane died; resubmit")),
                queued_s: 0.0,
                service_s: 0.0,
            });
        }
        done_rx
    }

    /// Non-blocking submit: fails fast when the lane queue is full, so
    /// upstream load balancers see backpressure instead of silent queueing.
    pub fn try_submit(
        &self,
        cfg: &EngineConfig,
        request: GenRequest,
    ) -> Result<Receiver<Completion>> {
        let (tx, generation) = self.lane_tx(cfg);
        let (done_tx, done_rx) = channel();
        match tx.try_send(Job {
            request,
            enqueued: Instant::now(),
            done: done_tx,
        }) {
            Ok(()) => {
                self.metrics.inc("requests_submitted");
                Ok(done_rx)
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.inc("requests_rejected");
                Err(anyhow!(
                    "lane queue full ({} deep): backpressure",
                    self.queue_depth
                ))
            }
            Err(TrySendError::Disconnected(_)) => {
                // Dead lane: drop *this incarnation* so the next submit
                // respawns fresh (generation-checked: never a healthy
                // respawn that beat us to it).
                self.evict_lane(&cfg.key(), generation);
                Err(anyhow!("server lane died; resubmit"))
            }
        }
    }

    /// Run a batch to completion (closed-loop), returning completions in
    /// submission order. A lane dying mid-request yields error
    /// completions for the affected requests rather than a panic.
    pub fn run_batch(&self, cfg: &EngineConfig, requests: Vec<GenRequest>) -> Vec<Completion> {
        let pairs: Vec<(GenRequest, Receiver<Completion>)> = requests
            .into_iter()
            .map(|r| {
                let rx = self.submit(cfg, r.clone());
                (r, rx)
            })
            .collect();
        pairs
            .into_iter()
            .map(|(request, rx)| {
                rx.recv().unwrap_or_else(|_| Completion {
                    request,
                    result: Err(anyhow!("server lane died mid-request")),
                    queued_s: 0.0,
                    service_s: 0.0,
                })
            })
            .collect()
    }

    /// Convenience: run a batch and return the successful results.
    pub fn run_batch_ok(&self, cfg: &EngineConfig, requests: Vec<GenRequest>) -> Result<Vec<GenResult>> {
        self.run_batch(cfg, requests)
            .into_iter()
            .map(|c| c.result)
            .collect()
    }

    /// Drop all lanes, joining worker threads.
    pub fn shutdown(&self) {
        let mut lanes = self.lanes.lock().unwrap();
        let drained: Vec<Lane> = std::mem::take(&mut *lanes).into_values().collect();
        for lane in drained {
            drop(lane.tx);
            for h in lane.handles {
                let _ = h.join();
            }
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> EngineConfig {
        EngineConfig::new("uvit_none", "baseline", None)
    }

    /// Server against a directory with no artifacts: lanes spawn, their
    /// engines fail init, and every job gets a clean error completion —
    /// which is all these eviction tests need (a live lane to evict).
    fn dead_dir_server() -> Server {
        Server::new(
            std::env::temp_dir().join("toma_no_such_artifacts"),
            1,
        )
    }

    #[test]
    fn stale_generation_cannot_evict_fresh_lane() {
        let server = dead_dir_server();
        let c = cfg();
        let (_tx, gen1) = server.lane_tx(&c);
        // A submitter that observed an *older* incarnation fail must not
        // evict the current lane.
        assert!(!server.evict_lane(&c.key(), gen1 + 1));
        assert!(!server.evict_lane(&c.key(), gen1.wrapping_sub(1)));
        assert_eq!(
            server.lanes.lock().unwrap().get(&c.key()).map(|l| l.generation),
            Some(gen1),
            "stale eviction must leave the live lane in place"
        );
        // The matching generation does evict.
        assert!(server.evict_lane(&c.key(), gen1));
        assert!(server.lanes.lock().unwrap().get(&c.key()).is_none());
        // A respawn gets a fresh identity, so the old generation is now
        // permanently stale.
        let (_tx, gen2) = server.lane_tx(&c);
        assert!(gen2 > gen1);
        assert!(!server.evict_lane(&c.key(), gen1));
        server.shutdown();
    }

    #[test]
    fn distinct_lanes_get_distinct_generations() {
        let server = dead_dir_server();
        let a = cfg();
        let mut b = cfg();
        b.steps = 7; // different key
        let (_ta, ga) = server.lane_tx(&a);
        let (_tb, gb) = server.lane_tx(&b);
        assert_ne!(ga, gb);
        // Re-fetching an existing lane reports the same generation.
        assert_eq!(server.lane_tx(&a).1, ga);
        server.shutdown();
    }

    #[test]
    fn engine_init_failure_yields_error_completion_not_eviction() {
        let server = dead_dir_server();
        let c = cfg();
        let rx = server.submit(&c, GenRequest::new("x", 1));
        let comp = rx.recv().expect("completion");
        let err = comp.result.err().expect("init must fail").to_string();
        assert!(err.contains("engine init failed"), "{err}");
        // The lane survives (init failure is not lane death).
        assert!(server.lanes.lock().unwrap().contains_key(&c.key()));
        server.shutdown();
    }
}
