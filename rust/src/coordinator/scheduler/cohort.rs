//! A *cohort*: plan-compatible requests advancing through the denoising
//! loop one batched step at a time, sharing a single [`PlanSlot`] — the
//! Sec. 4.3.2 reuse schedule made batch-level. The slot decides and counts
//! each plan action **once per cohort step**, not once per request, which
//! is exactly the amortization the serve_sweep bench measures.
//!
//! Membership changes on two edges only:
//!
//! * **join** — only at `RefreshAll` boundaries (or into an empty
//!   cohort). Every reuse window starts with a full refresh, so a member
//!   joining on a boundary observes from its local step 0 precisely the
//!   refresh cadence a dedicated per-request engine would give it; the
//!   refresh that admits it also rebuilds the shared plan for the grown
//!   membership. This is what keeps batched latents bit-identical to
//!   per-request ones.
//! * **leave** — on completion (the member ran its `cfg.steps` local
//!   steps). Its group block is dropped from the shared [`MergePlan`]
//!   mid-window; survivors keep their slices and the cadence bookkeeping
//!   (`dest_step` / `weight_step`) is untouched.
//!
//! Since PR 8 the cohort also owns a [`PlanCache`] *sibling* to the slot:
//! at every `RefreshAll` boundary the backend fingerprints its refresh
//! input and may downgrade the refresh to a cache install
//! ([`PlanAction::ReuseCached`]), skipping selection entirely. The cache
//! deliberately survives `PlanSlot::reset` across admissions, so
//! same-seed/same-prompt request families hit across requests on one lane.

use std::time::Instant;

use crate::coordinator::plan_cache::{PlanCache, PlanSlot, PlanStats};
use crate::coordinator::request::{EngineConfig, GenRequest, GenResult, GenStats};
use crate::toma::plan::PlanAction;
use crate::util::error::Result;

/// Per-request state while the request is in a cohort.
pub struct MemberState {
    pub request: GenRequest,
    /// Current latent, (C*H*W) single row (the CFG pair shares it).
    pub x: Vec<f32>,
    /// Prompt conditioning, (txt_len x txt_dim).
    pub cond: Vec<f32>,
    /// This member's own denoising step (0-based; the cohort step minus
    /// the join step).
    pub local_step: usize,
    pub stats: GenStats,
    /// Per-step global destination sets (only when `request.trace`),
    /// recorded by the backend — the Fig. 4 trace.
    pub dest_trace: Vec<Vec<usize>>,
    /// Scheduler-assigned identity, stable across membership changes.
    pub tag: u64,
}

/// The batched execution backend a cohort drives. [`super::HostBackend`]
/// implements it on the pure-Rust model; a PJRT batched-step backend can
/// plug in here once variable-batch artifacts exist — it inherits the
/// whole lane lifecycle (bounded queues, backpressure, evict/respawn,
/// deadline shedding, adaptive formation) from the unified
/// [`LaneFrontEnd`](crate::coordinator::LaneFrontEnd) for free, since the
/// scheduler's cohort job is already generic over this trait.
pub trait CohortBackend: Send {
    fn cfg(&self) -> &EngineConfig;
    /// Plan groups contributed per member (the region count; 1 for
    /// variants without merge plans).
    fn regions_per_member(&self) -> usize;
    /// Image tokens denoised per member per step (throughput accounting).
    fn tokens_per_member_step(&self) -> usize;
    /// Build fresh member state for an admitted request (`tag` is filled
    /// in by the cohort).
    fn admit(&self, request: &GenRequest) -> MemberState;
    /// Rerun destination selection and rebuild weights for every member
    /// in one batched call, installing the shared plan into `slot`.
    /// Probes `cache` first (PR 8): returns
    /// [`PlanAction::ReuseCached`] when the fingerprint of the refresh
    /// input matched a completed plan within the cache tolerance (the
    /// cache installed it into `slot`), [`PlanAction::RefreshAll`] when
    /// selection actually ran. With the cache disabled this is always
    /// `RefreshAll` and costs no fingerprint.
    fn refresh_all(
        &self,
        members: &[MemberState],
        slot: &mut PlanSlot,
        cache: &mut PlanCache,
        cohort_step: u64,
    ) -> Result<PlanAction>;
    /// Rebuild merge weights only, keeping the cached destinations.
    fn refresh_weights(
        &self,
        members: &[MemberState],
        slot: &mut PlanSlot,
        cohort_step: u64,
    ) -> Result<()>;
    /// One batched denoising step: advance every member's latent and
    /// `local_step` by one.
    fn step_batch(&self, members: &mut [MemberState], slot: &PlanSlot) -> Result<()>;
}

/// A member that finished this step.
pub struct CohortCompletion {
    pub tag: u64,
    pub request: GenRequest,
    pub result: Result<GenResult>,
}

/// What one cohort step did (the lane turns this into metrics/spans).
pub struct StepOutcome {
    /// The *effective* shared-slot action (None for plan-less variants):
    /// a scheduled `RefreshAll` that hit the plan cache surfaces here as
    /// [`PlanAction::ReuseCached`].
    pub action: Option<PlanAction>,
    /// Exact [`PlanStats`] movement this step (includes cache hit / miss /
    /// eviction counts the action alone cannot convey).
    pub plan_delta: PlanStats,
    /// Members that took part in this step.
    pub active_members: usize,
    /// Seconds spent on shared plan work this step (destination
    /// selection or weight refresh; 0 on reuse / plan-less variants).
    pub plan_s: f64,
    /// Seconds spent in the batched model step (the GEMM work).
    pub gemm_s: f64,
    pub completions: Vec<CohortCompletion>,
}

pub struct Cohort {
    backend: Box<dyn CohortBackend>,
    members: Vec<MemberState>,
    slot: PlanSlot,
    /// PR 8 fingerprint cache — a sibling of `slot`, so `slot.reset()` on
    /// re-admission leaves completed plans reusable across requests.
    cache: PlanCache,
    cohort_step: u64,
    next_tag: u64,
}

impl Cohort {
    pub fn new(backend: Box<dyn CohortBackend>) -> Cohort {
        let cache = PlanCache::from_config(backend.cfg());
        Cohort {
            backend,
            members: Vec::new(),
            slot: PlanSlot::default(),
            cache,
            cohort_step: 0,
            next_tag: 0,
        }
    }

    /// Is the fingerprinted plan cache active on this cohort's lane?
    pub fn cache_enabled(&self) -> bool {
        self.cache.enabled()
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    pub fn cohort_step(&self) -> u64 {
        self.cohort_step
    }

    pub fn cfg(&self) -> &EngineConfig {
        self.backend.cfg()
    }

    /// The shared slot's accumulated statistics (current cohort).
    pub fn plan_stats(&self) -> PlanStats {
        self.slot.stats
    }

    pub fn tokens_per_member_step(&self) -> usize {
        self.backend.tokens_per_member_step()
    }

    /// Seeds of every current member, in member order — the "in flight"
    /// set the fault injector's poison rules match against at the
    /// `scheduler.step` probe (and the quarantine layer's notion of who
    /// was aboard when a lane died).
    pub fn member_seeds(&self) -> Vec<u64> {
        self.members.iter().map(|m| m.request.seed).collect()
    }

    /// Can a request join right now? Plan-bearing cohorts accept members
    /// only when the *next* step's action is `RefreshAll`, so the
    /// newcomer's local cadence is exactly the per-request one.
    pub fn can_join(&self) -> bool {
        if self.members.is_empty() || !self.backend.cfg().needs_plan() {
            return true;
        }
        self.backend
            .cfg()
            .schedule
            .is_refresh_boundary(self.cohort_step, self.slot.img.as_ref())
    }

    /// Admit a request (resets to a fresh cohort when empty); returns the
    /// member tag used to match completions.
    pub fn admit(&mut self, request: &GenRequest) -> Result<u64> {
        crate::ensure!(self.can_join(), "cohort not at a refresh boundary");
        if self.members.is_empty() {
            self.slot.reset();
            self.cohort_step = 0;
        }
        let mut m = self.backend.admit(request);
        m.tag = self.next_tag;
        self.next_tag += 1;
        let tag = m.tag;
        self.members.push(m);
        Ok(tag)
    }

    /// Fail every in-flight member (backend error recovery); the cohort
    /// becomes empty and resets on the next admit.
    pub fn drain(&mut self) -> Vec<(u64, GenRequest)> {
        self.slot.reset();
        self.cohort_step = 0;
        self.members
            .drain(..)
            .map(|m| (m.tag, m.request))
            .collect()
    }

    /// One batched step: decide/refresh the shared plan once, run the
    /// batched backend step, then emit members that reached their final
    /// step (dropping their plan blocks so survivors keep their slices).
    pub fn step(&mut self) -> Result<StepOutcome> {
        if self.members.is_empty() {
            return Ok(StepOutcome {
                action: None,
                plan_delta: PlanStats::default(),
                active_members: 0,
                plan_s: 0.0,
                gemm_s: 0.0,
                completions: vec![],
            });
        }
        let needs_plan = self.backend.cfg().needs_plan();
        let schedule = self.backend.cfg().schedule;
        let mut action = None;
        let mut plan_s = 0.0;
        let stats_before = self.slot.stats;
        if needs_plan {
            let t_plan = Instant::now();
            let mut a = self.slot.decide(&schedule, self.cohort_step);
            match a {
                PlanAction::RefreshAll => {
                    // The backend may downgrade to ReuseCached on a
                    // fingerprint hit (PR 8).
                    a = self.backend.refresh_all(
                        &self.members,
                        &mut self.slot,
                        &mut self.cache,
                        self.cohort_step,
                    )?;
                }
                PlanAction::RefreshWeights => {
                    self.backend
                        .refresh_weights(&self.members, &mut self.slot, self.cohort_step)?
                }
                PlanAction::Reuse => {}
                PlanAction::ReuseCached => unreachable!("decide never yields ReuseCached"),
            }
            // Per-member stats mirror what a dedicated engine would count.
            let cache_on = self.cache.enabled();
            for m in &mut self.members {
                match a {
                    PlanAction::RefreshAll => {
                        m.stats.select_calls += 1;
                        if cache_on {
                            m.stats.plan_cache_misses += 1;
                        }
                    }
                    PlanAction::RefreshWeights => m.stats.weight_refreshes += 1,
                    PlanAction::Reuse => m.stats.plan_reuses += 1,
                    PlanAction::ReuseCached => m.stats.plan_cache_hits += 1,
                }
            }
            action = Some(a);
            plan_s = t_plan.elapsed().as_secs_f64();
        }
        let size = self.members.len();
        for m in &mut self.members {
            m.stats.cohort_size = m.stats.cohort_size.max(size);
        }
        let t_gemm = Instant::now();
        self.backend.step_batch(&mut self.members, &self.slot)?;
        let gemm_s = t_gemm.elapsed().as_secs_f64();
        for m in &mut self.members {
            m.stats.steps += 1;
        }
        self.cohort_step += 1;

        // Leave on completion.
        let total = self.backend.cfg().steps;
        let regions = self.backend.regions_per_member();
        let mut completions = vec![];
        let mut i = self.members.len();
        while i > 0 {
            i -= 1;
            if self.members[i].local_step >= total {
                let m = self.members.remove(i);
                if needs_plan {
                    if let Some(p) = self.slot.img.as_mut() {
                        p.remove_member(i, regions);
                    }
                }
                // Note on stats: count fields (select_calls, reuses, ...)
                // mirror a dedicated engine exactly; per-phase *timings*
                // are shared across the cohort and therefore not
                // attributable per member — the scheduler lane records
                // them in the metrics histograms (cohort_step_time) and
                // fills stats.total_s with the member's wall time.
                completions.push(CohortCompletion {
                    tag: m.tag,
                    request: m.request,
                    result: Ok(GenResult {
                        latent: m.x,
                        stats: m.stats,
                        dest_trace: m.dest_trace,
                    }),
                });
            }
        }
        completions.reverse(); // admission order among leavers
        Ok(StepOutcome {
            action,
            plan_delta: self.slot.stats.delta_since(&stats_before),
            active_members: size,
            plan_s,
            gemm_s,
            completions,
        })
    }
}
