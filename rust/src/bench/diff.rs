//! Bench trend tracking: compare two `BENCH_<target>.json` records (as
//! written by [`super::Runner`] with `--json`) by median and flag
//! regressions — the engine behind `toma-serve bench-diff` and the CI
//! perf gate (ROADMAP "bench trend tracking").

use std::collections::BTreeMap;

use crate::util::error::Result;
use crate::util::json::Json;
use crate::anyhow;

/// One case present in both records.
#[derive(Clone, Debug)]
pub struct DiffRow {
    pub name: String,
    pub old_median_s: f64,
    pub new_median_s: f64,
}

impl DiffRow {
    /// new / old: 1.0 = unchanged, above 1.0 = slower.
    pub fn ratio(&self) -> f64 {
        if self.old_median_s <= 0.0 {
            1.0
        } else {
            self.new_median_s / self.old_median_s
        }
    }
}

/// Comparison of two bench records.
#[derive(Debug, Default)]
pub struct DiffReport {
    pub rows: Vec<DiffRow>,
    /// Cases only in the old record (removed benches).
    pub only_old: Vec<String>,
    /// Cases only in the new record (added benches).
    pub only_new: Vec<String>,
}

/// Extract `name -> median_s` from a bench JSON document.
pub fn parse_medians(json: &str) -> Result<BTreeMap<String, f64>> {
    let doc = Json::parse(json).map_err(|e| anyhow!("bench json: {e}"))?;
    let rows = doc
        .get("results")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("bench json has no `results` array"))?;
    let mut out = BTreeMap::new();
    for r in rows {
        let name = r
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("bench result without `name`"))?;
        let median = r
            .get("median_s")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("bench result `{name}` without `median_s`"))?;
        out.insert(name.to_string(), median);
    }
    Ok(out)
}

/// Diff two bench JSON documents (old baseline vs new run).
pub fn diff(old_json: &str, new_json: &str) -> Result<DiffReport> {
    let old = parse_medians(old_json)?;
    let mut new = parse_medians(new_json)?;
    let mut report = DiffReport::default();
    for (name, old_median_s) in old {
        match new.remove(&name) {
            Some(new_median_s) => report.rows.push(DiffRow {
                name,
                old_median_s,
                new_median_s,
            }),
            None => report.only_old.push(name),
        }
    }
    report.only_new = new.into_keys().collect();
    Ok(report)
}

impl DiffReport {
    /// Cases slower than `(1 + tolerance)x`, ignoring medians below
    /// `min_median_s` on either side (timer noise dominates down there).
    pub fn regressions(&self, tolerance: f64, min_median_s: f64) -> Vec<&DiffRow> {
        self.rows
            .iter()
            .filter(|r| {
                r.old_median_s >= min_median_s
                    && r.new_median_s >= min_median_s
                    && r.ratio() > 1.0 + tolerance
            })
            .collect()
    }

    /// Human-readable comparison table.
    pub fn render(&self, tolerance: f64, min_median_s: f64) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<44} {:>12} {:>12} {:>8}\n",
            "case", "old median", "new median", "ratio"
        ));
        for r in &self.rows {
            let flag = if r.old_median_s >= min_median_s
                && r.new_median_s >= min_median_s
                && r.ratio() > 1.0 + tolerance
            {
                "  REGRESSED"
            } else {
                ""
            };
            out.push_str(&format!(
                "{:<44} {:>12} {:>12} {:>7.2}x{}\n",
                r.name,
                crate::report::fmt_secs(r.old_median_s),
                crate::report::fmt_secs(r.new_median_s),
                r.ratio(),
                flag
            ));
        }
        for n in &self.only_old {
            out.push_str(&format!("{n:<44} removed\n"));
        }
        for n in &self.only_new {
            out.push_str(&format!("{n:<44} new\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(cases: &[(&str, f64)]) -> String {
        let rows: Vec<String> = cases
            .iter()
            .map(|(n, m)| {
                format!(
                    "{{\"name\": \"{n}\", \"median_s\": {m:e}, \"p10_s\": {m:e}, \
                     \"p90_s\": {m:e}, \"mean_s\": {m:e}, \"iters\": 5}}"
                )
            })
            .collect();
        format!("{{\"bench\": \"t\", \"results\": [{}]}}", rows.join(","))
    }

    #[test]
    fn parses_runner_output_format() {
        let mut r = crate::bench::Runner::new();
        r.min_time_s = 0.001;
        r.max_iters = 3;
        r.bench("case_a", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        let medians = parse_medians(&r.to_json()).expect("parse");
        assert!(medians.contains_key("case_a"));
    }

    #[test]
    fn flags_regressions_beyond_tolerance() {
        let old = record(&[("fast", 1e-3), ("slow", 2e-3), ("tiny", 1e-6)]);
        let new = record(&[("fast", 1.05e-3), ("slow", 3e-3), ("tiny", 5e-6)]);
        let report = diff(&old, &new).expect("diff");
        let regs = report.regressions(0.15, 5e-5);
        assert_eq!(regs.len(), 1, "only `slow` regresses: {regs:?}");
        assert_eq!(regs[0].name, "slow");
        assert!((regs[0].ratio() - 1.5).abs() < 1e-9);
        // `tiny` is under the noise floor, `fast` within tolerance.
        let render = report.render(0.15, 5e-5);
        assert!(render.contains("REGRESSED"));
    }

    #[test]
    fn tracks_added_and_removed_cases() {
        let old = record(&[("a", 1e-3), ("gone", 1e-3)]);
        let new = record(&[("a", 1e-3), ("added", 1e-3)]);
        let report = diff(&old, &new).expect("diff");
        assert_eq!(report.only_old, vec!["gone".to_string()]);
        assert_eq!(report.only_new, vec!["added".to_string()]);
        assert!(report.regressions(0.15, 0.0).is_empty());
    }

    #[test]
    fn rejects_malformed_json() {
        assert!(diff("not json", "{}").is_err());
        assert!(diff("{\"results\": 3}", "{\"results\": []}").is_err());
    }
}
