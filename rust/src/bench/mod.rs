//! Criterion-style micro-benchmark harness (the vendored crate set has no
//! `criterion`): warmup, timed iterations, median/p10/p90 with outlier
//! trimming, and a `--filter` / `--quick` / `--json <path>` aware runner
//! for `cargo bench` targets (`harness = false`).
//!
//! With `--json <path>` (or `TOMA_BENCH_JSON=<path>`), the runner writes
//! `BENCH_<target>.json` — machine-readable `(name, median_s, p10_s,
//! p90_s, mean_s, iters)` records — when it is dropped, so the perf
//! trajectory of every PR can be diffed without scraping stdout. If
//! `<path>` is an existing directory the file is created inside it;
//! otherwise `<path>` is used verbatim.

pub mod diff;

use std::path::PathBuf;
use std::time::Instant;

use crate::util::stats;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
}

impl BenchResult {
    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>12} median  {:>12} p90  ({} iters)",
            self.name,
            crate::report::fmt_secs(self.median_s),
            crate::report::fmt_secs(self.p90_s),
            self.iters
        )
    }
}

/// Benchmark runner configured from CLI args.
pub struct Runner {
    pub filter: Option<String>,
    /// Minimum sampling time per case, seconds.
    pub min_time_s: f64,
    pub min_iters: usize,
    pub max_iters: usize,
    pub results: Vec<BenchResult>,
    /// Where to write the JSON record on drop (`--json <path>`).
    pub json: Option<PathBuf>,
    /// Free-form environment annotations serialized into the JSON record
    /// (e.g. which microkernel dispatch actually ran), so records stay
    /// comparable across hosts. Ignored by `bench::diff`.
    pub notes: Vec<(String, String)>,
}

impl Default for Runner {
    fn default() -> Self {
        Runner::new()
    }
}

impl Runner {
    pub fn new() -> Self {
        Runner {
            filter: None,
            min_time_s: 0.5,
            min_iters: 5,
            max_iters: 1000,
            results: vec![],
            json: None,
            notes: vec![],
        }
    }

    /// Record an environment annotation for the JSON record (last write
    /// wins for a repeated key).
    pub fn note(&mut self, key: &str, value: &str) {
        self.notes.retain(|(k, _)| k != key);
        self.notes.push((key.to_string(), value.to_string()));
    }

    /// Configure from `cargo bench -- [filter] [--quick] [--json <path>]`
    /// style args.
    pub fn from_args() -> Self {
        let mut r = Runner::new();
        let mut args = std::env::args().skip(1).peekable();
        while let Some(a) = args.next() {
            match a.as_str() {
                "--quick" => {
                    r.min_time_s = 0.05;
                    r.min_iters = 2;
                    r.max_iters = 20;
                }
                "--json" => {
                    // Only consume a real value; `--json --quick` must not
                    // eat the following flag.
                    match args.peek() {
                        Some(v) if !v.starts_with('-') => {
                            r.json = args.next().map(PathBuf::from);
                        }
                        _ => eprintln!("[bench] --json requires a path; ignoring"),
                    }
                }
                "--bench" | "--exact" => {}
                s if !s.starts_with('-') => r.filter = Some(s.to_string()),
                _ => {}
            }
        }
        if std::env::var("TOMA_BENCH_QUICK").is_ok() {
            r.min_time_s = 0.05;
            r.min_iters = 2;
            r.max_iters = 20;
        }
        if r.json.is_none() {
            if let Ok(p) = std::env::var("TOMA_BENCH_JSON") {
                r.json = Some(PathBuf::from(p));
            }
        }
        r
    }

    /// The bench target name: the executable stem minus cargo's `-<hash>`.
    fn target_name() -> String {
        let exe = std::env::args().next().unwrap_or_default();
        let stem = std::path::Path::new(&exe)
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("bench")
            .to_string();
        match stem.rsplit_once('-') {
            Some((base, hash))
                if hash.len() == 16 && hash.bytes().all(|b| b.is_ascii_hexdigit()) =>
            {
                base.to_string()
            }
            _ => stem,
        }
    }

    /// Render the recorded results as a JSON document.
    pub fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        let rows: Vec<String> = self
            .results
            .iter()
            .map(|r| {
                format!(
                    "  {{\"name\": \"{}\", \"median_s\": {:e}, \"p10_s\": {:e}, \
                     \"p90_s\": {:e}, \"mean_s\": {:e}, \"iters\": {}}}",
                    esc(&r.name),
                    r.median_s,
                    r.p10_s,
                    r.p90_s,
                    r.mean_s,
                    r.iters
                )
            })
            .collect();
        let notes: Vec<String> = self
            .notes
            .iter()
            .map(|(k, v)| format!("\"{}\": \"{}\"", esc(k), esc(v)))
            .collect();
        format!(
            "{{\"bench\": \"{}\", \"notes\": {{{}}}, \"results\": [\n{}\n]}}\n",
            esc(&Self::target_name()),
            notes.join(", "),
            rows.join(",\n")
        )
    }

    /// Write the JSON record now (also runs on drop when `--json` is set).
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<PathBuf> {
        let path = if path.is_dir() {
            path.join(format!("BENCH_{}.json", Self::target_name()))
        } else {
            path.to_path_buf()
        };
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }

    pub fn should_run(&self, name: &str) -> bool {
        self.filter
            .as_ref()
            .map(|f| name.contains(f.as_str()))
            .unwrap_or(true)
    }

    /// Time `f`, printing and recording the result. Returns median seconds.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> f64 {
        if !self.should_run(name) {
            return 0.0;
        }
        // Warmup: one untimed call plus enough to estimate cost.
        let t0 = Instant::now();
        f();
        let first = t0.elapsed().as_secs_f64();
        let target_iters = ((self.min_time_s / first.max(1e-9)) as usize)
            .clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(target_iters);
        for _ in 0..target_iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        // Trim top/bottom 10% against scheduler noise.
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let trim = samples.len() / 10;
        let trimmed = &samples[trim..samples.len() - trim.min(samples.len() - 1)];
        let result = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            median_s: stats::median(trimmed),
            mean_s: stats::mean(trimmed),
            p10_s: stats::percentile(&samples, 10.0),
            p90_s: stats::percentile(&samples, 90.0),
        };
        println!("{}", result.summary());
        let med = result.median_s;
        self.results.push(result);
        med
    }

    /// Look up a recorded result by exact name.
    pub fn get(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }
}

impl Drop for Runner {
    fn drop(&mut self) {
        let Some(path) = self.json.clone() else {
            return;
        };
        if self.results.is_empty() {
            return;
        }
        // A panicking bench run would serialize a truncated result set that
        // a perf-diff pipeline couldn't tell from a healthy one — skip it.
        if std::thread::panicking() {
            eprintln!("[bench] run panicked; not writing {}", path.display());
            return;
        }
        match self.write_json(&path) {
            Ok(p) => eprintln!("[bench] wrote {}", p.display()),
            Err(e) => eprintln!("[bench] writing {}: {e}", path.display()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_result() {
        let mut r = Runner::new();
        r.min_time_s = 0.01;
        r.max_iters = 10;
        let med = r.bench("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(med >= 0.0);
        assert_eq!(r.results.len(), 1);
        assert!(r.get("spin").is_some());
        assert!(r.get("missing").is_none());
    }

    #[test]
    fn filter_skips() {
        let mut r = Runner::new();
        r.filter = Some("match".into());
        assert!(r.should_run("a_match_b"));
        assert!(!r.should_run("other"));
        let ran = std::cell::Cell::new(false);
        r.bench("other", || ran.set(true));
        assert!(!ran.get());
        assert!(r.results.is_empty());
    }

    #[test]
    fn json_record_roundtrips_fields() {
        let mut r = Runner::new();
        r.min_time_s = 0.001;
        r.max_iters = 3;
        r.bench("alpha", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        let j = r.to_json();
        assert!(j.contains("\"name\": \"alpha\""));
        assert!(j.contains("median_s"));
        assert!(j.contains("p90_s"));
        let parsed = crate::util::json::Json::parse(&j).expect("valid json");
        let rows = parsed.get("results").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].get("iters").and_then(|v| v.as_usize()).unwrap() >= 1);
    }

    #[test]
    fn notes_serialize_and_dedupe() {
        let mut r = Runner::new();
        r.min_time_s = 0.001;
        r.max_iters = 3;
        r.note("kernel_dispatch", "scalar");
        r.note("kernel_dispatch", "avx2+fma"); // last write wins
        r.bench("noted", || {
            std::hint::black_box((0..10).sum::<u64>());
        });
        let j = r.to_json();
        assert!(j.contains("\"kernel_dispatch\": \"avx2+fma\""));
        assert!(!j.contains("\"kernel_dispatch\": \"scalar\""));
        let parsed = crate::util::json::Json::parse(&j).expect("valid json");
        let note = parsed
            .get("notes")
            .and_then(|n| n.get("kernel_dispatch"))
            .and_then(|v| v.as_str())
            .expect("note present");
        assert_eq!(note, "avx2+fma");
        // diff still reads the results regardless of notes.
        assert!(diff::parse_medians(&j).expect("medians").contains_key("noted"));
    }

    #[test]
    fn ordering_sane_for_different_costs() {
        let mut r = Runner::new();
        r.min_time_s = 0.02;
        r.max_iters = 50;
        // black_box the *bounds* so the compiler cannot constant-fold the
        // loops away in release mode.
        let fast = r.bench("fast", || {
            let n = std::hint::black_box(100u64);
            std::hint::black_box((0..n).map(|x| x.wrapping_mul(x)).sum::<u64>());
        });
        let slow = r.bench("slow", || {
            let n = std::hint::black_box(1_000_000u64);
            std::hint::black_box((0..n).map(|x| x.wrapping_mul(x)).sum::<u64>());
        });
        assert!(slow > fast);
    }
}
